//! Multi-producer pipeline regression tests: the CPU sampling-worker count
//! is a *scheduling* choice, never a semantic one — training trajectories
//! are bit-identical for producers ∈ {1, 2, 4}, with and without the
//! pipeline, single-backend and replica-fanned — and the steady-state CPU
//! producer path performs **zero heap allocations** per batch (same
//! counter style as the arena tests in `tests/perf_path.rs`).

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, TrainCfg, Trainer,
    DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::SimBackend;

/// batch_size 4 on tiny's 24 train seeds = 6 batches/epoch, so every
/// producer count in {1, 2, 4} gets a non-trivial stride of the schedule.
fn cfg(producers: usize) -> TrainCfg {
    TrainCfg {
        epochs: 1,
        batch_size: 4,
        fanout: 3,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers,
    }
}

fn trainer_trajectory(model: ModelKind, opt: OptConfig, producers: usize) -> Vec<(f64, f64)> {
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, model, opt, cfg(producers)).unwrap();
    (0..3)
        .map(|e| {
            let m = tr.train_epoch(e).unwrap();
            (m.loss, m.acc)
        })
        .collect()
}

/// The headline contract: pipelined training follows a bitwise-identical
/// trajectory for 1, 2 and 4 producers — and matches the non-pipelined
/// (inline, single-producer) path too, for both models and for the
/// baseline plan (whose selection runs through `edge_select` dispatches).
#[test]
fn producer_count_never_changes_the_trajectory() {
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let piped = OptConfig::hifuse();
        let unpiped = OptConfig { pipeline: false, ..piped };
        let inline = trainer_trajectory(model, unpiped, 1);
        for producers in [1usize, 2, 4] {
            let t = trainer_trajectory(model, piped, producers);
            assert_eq!(
                t,
                inline,
                "{}: {producers} producers diverged from the inline path",
                model.name()
            );
        }
    }
    // Baseline plan (no offload): the pipeline still only moves collection
    // off-thread; selection dispatches stay on the consumer.
    let base_pipe = OptConfig { pipeline: true, ..OptConfig::baseline() };
    let a = trainer_trajectory(ModelKind::Rgcn, base_pipe, 1);
    let b = trainer_trajectory(ModelKind::Rgcn, base_pipe, 4);
    assert_eq!(a, b, "baseline plan diverged across producer counts");
}

fn replica_trajectory(replicas: usize, producers: usize, pipeline: bool) -> Vec<(f64, f64)> {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let t = replica_thread_budget(4, replicas);
    let engines: Vec<SimBackend> =
        (0..replicas).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, &g, ModelKind::Rgcn, opt, cfg(producers), DEFAULT_ROUND)
            .unwrap();
    (0..2)
        .map(|e| {
            let m = grp.train_epoch(e).unwrap();
            (m.group.loss, m.group.acc)
        })
        .collect()
}

/// The full grid the issue pins: producers ∈ {1, 2, 4} × replicas ∈ {1, 2}
/// × pipeline on/off — one bitwise trajectory.
#[test]
fn producers_replicas_pipeline_grid_is_bit_identical() {
    let reference = replica_trajectory(1, 1, false);
    for replicas in [1usize, 2] {
        for producers in [1usize, 2, 4] {
            for pipeline in [false, true] {
                let t = replica_trajectory(replicas, producers, pipeline);
                assert_eq!(
                    t, reference,
                    "replicas={replicas} producers={producers} pipeline={pipeline} diverged"
                );
            }
        }
    }
}

/// Zero steady-state producer allocations, sequential path: the cumulative
/// pool stats (`EpochMetrics::producer`, same snapshot semantics as the
/// arena) show no fresh buffer sets and no buffer growth after the warm-up
/// epoch — only reuse.
#[test]
fn sequential_producer_reaches_zero_steady_state_allocations() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let opt = OptConfig { pipeline: false, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg(1)).unwrap();
    let warm = tr.train_epoch(0).unwrap().producer;
    assert!(warm.fresh > 0, "warm-up epoch should construct buffer sets");
    let m1 = tr.train_epoch(1).unwrap().producer;
    let m2 = tr.train_epoch(2).unwrap().producer;
    for (epoch, (prev, now)) in [(1u64, (warm, m1)), (2, (m1, m2))] {
        assert_eq!(
            now.fresh, prev.fresh,
            "epoch {epoch}: steady state constructed a fresh buffer set ({prev:?} -> {now:?})"
        );
        assert_eq!(
            now.grown, prev.grown,
            "epoch {epoch}: steady state grew a pooled buffer ({prev:?} -> {now:?})"
        );
        assert!(now.reused > prev.reused, "epoch {epoch}: pool unused");
    }
}

/// Zero steady-state producer allocations, pipelined multi-producer path:
/// the circulating buffer population (producers × depth) is built during
/// warm-up and then recycles forever.
#[test]
fn pipelined_producers_reach_zero_steady_state_allocations() {
    for producers in [1usize, 2, 4] {
        let eng = SimBackend::builtin_threaded("tiny", 2).unwrap();
        let opt = OptConfig::hifuse();
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg(producers)).unwrap();
        tr.train_epoch(0).unwrap();
        let warm = tr.train_epoch(1).unwrap().producer;
        let steady = tr.train_epoch(2).unwrap().producer;
        assert_eq!(
            steady.fresh, warm.fresh,
            "{producers} producers: steady state constructed a buffer set \
             ({warm:?} -> {steady:?})"
        );
        assert_eq!(
            steady.grown, warm.grown,
            "{producers} producers: steady state grew a pooled buffer \
             ({warm:?} -> {steady:?})"
        );
        assert!(steady.reused > warm.reused, "{producers} producers: pool unused");
    }
}

/// Replica lanes inherit the contract: every lane's producer pool reaches
/// steady state (per-replica cumulative stats flat across epochs), with
/// the pipeline fan-out on.
#[test]
fn replica_lane_producers_reach_zero_steady_state_allocations() {
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let t = replica_thread_budget(4, 2);
    let engines: Vec<SimBackend> =
        (0..2).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, &g, ModelKind::Rgcn, opt, cfg(2), DEFAULT_ROUND).unwrap();
    let ms: Vec<_> = (0..3u64).map(|e| grp.train_epoch(e).unwrap()).collect();
    for lane in 0..2 {
        let warm = ms[1].per_replica[lane].producer;
        let steady = ms[2].per_replica[lane].producer;
        assert_eq!(
            steady.fresh, warm.fresh,
            "lane {lane}: steady state constructed a buffer set ({warm:?} -> {steady:?})"
        );
        assert_eq!(
            steady.grown, warm.grown,
            "lane {lane}: steady state grew a pooled buffer ({warm:?} -> {steady:?})"
        );
        assert!(steady.reused > warm.reused, "lane {lane}: pool unused");
    }
    // Group totals absorb the per-lane pools.
    let sum: u64 = ms[2].per_replica.iter().map(|r| r.producer.reused).sum();
    assert_eq!(ms[2].group.producer.reused, sum);
}

/// The per-stage CPU timing breakdown is populated and consistent:
/// sample + select + collect is bounded by the total CPU time, and the
/// sampling stage is never zero across a full epoch.
#[test]
fn cpu_stage_times_are_populated() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg(2)).unwrap();
    let m = tr.train_epoch(0).unwrap();
    assert!(m.cpu_by_stage.total() > std::time::Duration::ZERO, "no CPU stage time recorded");
    assert!(
        m.cpu_by_stage.total() <= m.cpu_time,
        "stage breakdown exceeds total cpu time: {:?} > {:?}",
        m.cpu_by_stage.total(),
        m.cpu_time
    );
    assert!(m.cpu_by_stage.sample > std::time::Duration::ZERO, "sampling time missing");
}
