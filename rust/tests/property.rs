//! Property-based tests: seeded randomized sweeps asserting invariants
//! (the offline environment has no proptest crate; these loops play the
//! same role — many random cases per property, deterministic seeds so
//! failures reproduce).

use hifuse::coordinator::OptConfig;
use hifuse::graph::datasets::{generate, DatasetSpec};
use hifuse::graph::Layout;
use hifuse::models::plan::expected_counts;
use hifuse::models::step::{pad_layer_edges, Dims};
use hifuse::models::ModelKind;
use hifuse::sampler::{NeighborSampler, SamplerCfg, TaggedEdges};
use hifuse::semantic;
use hifuse::util::Rng;

const CASES: u64 = 25;

fn random_spec(rng: &mut Rng) -> DatasetSpec {
    DatasetSpec {
        name: "prop",
        nodes: 100 + rng.below(400),
        edges: 300 + rng.below(2000),
        n_types: 2 + rng.below(6),
        n_relations: 2 + rng.below(10),
        num_classes: 2 + rng.below(3),
        train_size: 16 + rng.below(32),
    }
}

/// Sampler invariants hold for arbitrary graphs and seeds.
#[test]
fn prop_sampler_invariants() {
    let mut meta = Rng::new(0xA11CE);
    for case in 0..CASES {
        let spec = random_spec(&mut meta);
        let g = generate(&spec, 8, 1.0, case);
        let cfg = SamplerCfg {
            batch_size: 4 + meta.below(8),
            fanout: 1 + meta.below(4),
            layers: 2,
            ns: 32,
            ep: 16,
        };
        let s = NeighborSampler::new(&g, cfg);
        let mb = s.sample(&Rng::new(case), case, meta.below(3));

        // (1) slot maps are injective and in-range, capped at ns.
        for (t, slots) in mb.slots.iter().enumerate() {
            assert!(slots.len() <= cfg.ns);
            let mut u = slots.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), slots.len(), "case {case}: dup slot type {t}");
            for &v in slots {
                assert!((v as usize) < g.num_nodes[t]);
            }
        }
        // (2) every sampled edge exists in the graph, per-relation <= ep.
        for layer in &mb.oracle_edges {
            for (ri, e) in layer.iter().enumerate() {
                assert!(e.len() <= cfg.ep);
                let rel = &g.relations[ri];
                for i in 0..e.len() {
                    let sv = mb.slots[rel.src_type][e.src[i] as usize];
                    let dv = mb.slots[rel.dst_type][e.dst[i] as usize];
                    assert!(rel.in_neighbors(dv as usize).contains(&sv), "case {case}");
                }
            }
        }
        // (3) tagged list is a permutation of the oracle edges.
        for (l, t) in mb.tagged.iter().enumerate() {
            let total: usize = mb.oracle_edges[l].iter().map(|e| e.len()).sum();
            assert_eq!(t.len(), total, "case {case} layer {l}");
        }
    }
}

/// All three CPU selection implementations agree on random inputs, for any
/// thread count.
#[test]
fn prop_selection_implementations_agree() {
    let mut meta = Rng::new(0xB0B);
    for case in 0..CASES * 2 {
        let n_rel = 1 + meta.below(20);
        let n = meta.below(3000);
        let mut t = TaggedEdges::default();
        let mut rng = Rng::new(case);
        for _ in 0..n {
            t.rel.push(rng.below(n_rel) as u32);
            t.src.push(rng.next_u64() as u32 % 512);
            t.dst.push(rng.next_u64() as u32 % 512);
        }
        let a = semantic::select_serial(&t, n_rel);
        let b = semantic::select_parallel(&t, n_rel, 1 + meta.below(8));
        let c = semantic::select_bucketed(&t, n_rel);
        for r in 0..n_rel {
            assert_eq!(a[r].src, b[r].src, "case {case} rel {r} parallel");
            assert_eq!(a[r].src, c[r].src, "case {case} rel {r} bucketed");
            assert_eq!(a[r].dst, c[r].dst, "case {case} rel {r} bucketed dst");
        }
        // Selection partitions the input: total edges preserved.
        let total: usize = a.iter().map(|e| e.len()).sum();
        assert_eq!(total, t.len(), "case {case}");
    }
}

/// Merged edge tensors always mirror the per-relation padded tensors.
#[test]
fn prop_pad_layer_edges_consistency() {
    let d = Dims { ns: 32, ep: 16, rpad: 8, tpad: 8, f: 8, h: 16, c: 4, elp: 128 };
    let mut meta = Rng::new(0xC0DE);
    for case in 0..CASES {
        let mut rng = Rng::new(case);
        let rels: Vec<hifuse::sampler::RelEdges> = (0..meta.below(d.rpad + 1))
            .map(|_| {
                let n = rng.below(d.ep + 1);
                hifuse::sampler::RelEdges {
                    src: (0..n).map(|_| rng.below(d.ns) as u32).collect(),
                    dst: (0..n).map(|_| rng.below(d.ns) as u32).collect(),
                }
            })
            .collect();
        let le = pad_layer_edges(&rels, &d);
        let ms = le.src.as_i32().unwrap();
        let md = le.dst.as_i32().unwrap();
        let mv = le.valid.as_f32().unwrap();
        for r in 0..d.rpad {
            let (s, t, v) = &le.per_rel[r];
            assert_eq!(&ms[r * d.ep..(r + 1) * d.ep], s.as_i32().unwrap());
            assert_eq!(&md[r * d.ep..(r + 1) * d.ep], t.as_i32().unwrap());
            assert_eq!(&mv[r * d.ep..(r + 1) * d.ep], v.as_f32().unwrap());
            // valid mask counts the real edges, padding is zeroed.
            let n = rels.get(r).map(|e| e.len()).unwrap_or(0);
            let pop: f32 = v.as_f32().unwrap().iter().sum();
            assert_eq!(pop as usize, n, "case {case} rel {r}");
        }
        // live <=> nonzero valid population.
        for r in 0..d.rpad {
            let n = rels.get(r).map(|e| e.len()).unwrap_or(0);
            assert_eq!(le.live.contains(&r), n > 0, "case {case} rel {r}");
        }
    }
}

/// Feature layout conversion is lossless for arbitrary stores.
#[test]
fn prop_feature_layout_roundtrip() {
    let mut meta = Rng::new(0xFEA7);
    for case in 0..CASES {
        let n_types = 1 + meta.below(6);
        let num_nodes: Vec<usize> = (0..n_types).map(|_| 1 + meta.below(50)).collect();
        let dim = 1 + meta.below(12);
        let labels: Vec<u8> = (0..num_nodes[0]).map(|_| meta.below(3) as u8).collect();
        let mut rng = Rng::new(case);
        let mut store =
            hifuse::graph::FeatureStore::synth(&num_nodes, dim, 0, &labels, 3, &mut rng);
        let mut row = vec![0.0f32; dim];
        let mut snapshot = Vec::new();
        for (t, &n) in num_nodes.iter().enumerate() {
            for v in 0..n {
                store.copy_row(t, v, &mut row);
                snapshot.push(row.clone());
            }
        }
        store.ensure_layout(Layout::IndexMajor);
        store.ensure_layout(Layout::TypeMajor);
        let mut i = 0;
        for (t, &n) in num_nodes.iter().enumerate() {
            for v in 0..n {
                store.copy_row(t, v, &mut row);
                assert_eq!(row, snapshot[i], "case {case} ({t},{v})");
                i += 1;
            }
        }
    }
}

/// The kernel-count model is monotone: every optimization can only reduce
/// (never increase) the dispatch count, for arbitrary live-relation counts.
#[test]
fn prop_plan_monotone_in_optimizations() {
    let mut meta = Rng::new(0x9_1A7);
    for case in 0..CASES * 2 {
        let n_rel = 1 + meta.below(150);
        let live = vec![meta.below(n_rel + 1), meta.below(n_rel + 1)];
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let base = expected_counts(model, &OptConfig::baseline(), n_rel, &live).total();
            let merged = expected_counts(
                model,
                &OptConfig { merge: true, ..OptConfig::baseline() },
                n_rel,
                &live,
            )
            .total();
            let off = expected_counts(
                model,
                &OptConfig { offload: true, ..OptConfig::baseline() },
                n_rel,
                &live,
            )
            .total();
            let hifuse = expected_counts(model, &OptConfig::hifuse(), n_rel, &live).total();
            let stacked =
                expected_counts(model, &OptConfig::parse("hifuse+stacked").unwrap(), n_rel, &live)
                    .total();
            assert!(merged <= base, "case {case}");
            assert!(off <= base, "case {case}");
            assert!(hifuse <= merged.min(off), "case {case}");
            assert!(stacked <= hifuse, "case {case}");
        }
    }
}

/// Generated datasets always expose a learnable, well-formed task.
#[test]
fn prop_generator_well_formed() {
    let mut meta = Rng::new(0x6E4);
    for case in 0..CASES {
        let spec = random_spec(&mut meta);
        let g = generate(&spec, 8, 1.0, case);
        assert_eq!(g.n_relations(), spec.n_relations);
        assert_eq!(g.n_types(), spec.n_types);
        // Self-relation present for the RGCN self-loop path.
        assert_eq!(g.relations[0].src_type, g.target_type);
        assert_eq!(g.relations[0].dst_type, g.target_type);
        assert_eq!(g.relations[0].num_edges(), g.num_nodes[g.target_type]);
        // Every vertex's self edge points at itself.
        for v in 0..g.num_nodes[g.target_type] {
            assert_eq!(g.relations[0].in_neighbors(v), &[v as u32]);
        }
        assert!(!g.train_idx.is_empty());
    }
}
