//! Replica-parity regression tests (DESIGN.md §4): the data-parallel
//! replica path is a *scheduling* choice, never a semantic one — the
//! training trajectory is bit-identical for any replica count, per-replica
//! counters sum to the group totals, and each replica's buffer arena still
//! reaches zero steady-state allocations per step.

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, ReplicaMetrics,
    TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::SimBackend;

/// batch_size 4 on tiny's 24 train seeds = 6 batches/epoch: with the
/// default round of 4 that is one full round plus a tail round of 2, so
/// every partition/merge edge case is exercised.
fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 4, fanout: 3, lr: 0.05, seed: 42, threads: 4, producers: 0 }
}

/// `n` sim backends sharing one 4-thread budget (so replica counts also
/// vary the per-lane kernel thread count — parity must hold regardless).
fn engines(n: usize) -> Vec<SimBackend> {
    let t = replica_thread_budget(4, n);
    (0..n).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect()
}

fn trajectory(model: ModelKind, opt: OptConfig, n: usize, round: usize) -> Vec<(f64, f64)> {
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp = ReplicaGroup::new(engines(n), &g, model, opt, cfg(), round).unwrap();
    (0..2)
        .map(|e| {
            let m = grp.train_epoch(e).unwrap();
            (m.group.loss, m.group.acc)
        })
        .collect()
}

/// The headline contract: `--replicas {1,2,4}` produce bitwise-identical
/// loss/accuracy trajectories, for both models and for the baseline plan
/// (whose edge-index selection runs through per-replica backends).
#[test]
fn replica_count_never_changes_the_trajectory() {
    for (model, mode) in [
        (ModelKind::Rgcn, "hifuse"),
        (ModelKind::Rgat, "hifuse"),
        (ModelKind::Rgcn, "base"),
    ] {
        let opt = OptConfig::parse(mode).unwrap();
        let one = trajectory(model, opt, 1, DEFAULT_ROUND);
        let two = trajectory(model, opt, 2, DEFAULT_ROUND);
        let four = trajectory(model, opt, 4, DEFAULT_ROUND);
        assert_eq!(one, two, "{} {mode}: 1 vs 2 replicas diverged", model.name());
        assert_eq!(one, four, "{} {mode}: 1 vs 4 replicas diverged", model.name());
    }
}

/// Rounds that don't divide evenly across lanes (round 3 over 2 replicas)
/// must still merge in global batch order; a replica count above the round
/// width is rejected at construction (such lanes could never work).
#[test]
fn non_divisible_rounds_keep_parity() {
    let opt = OptConfig::hifuse();
    let one = trajectory(ModelKind::Rgcn, opt, 1, 3);
    let two = trajectory(ModelKind::Rgcn, opt, 2, 3);
    let three = trajectory(ModelKind::Rgcn, opt, 3, 3);
    assert_eq!(one, two);
    assert_eq!(one, three);

    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    assert!(
        ReplicaGroup::new(engines(4), &g, ModelKind::Rgcn, opt, cfg(), 3).is_err(),
        "4 replicas over a 3-batch round must be rejected"
    );
}

/// The producer fan-out is pure scheduling too: pipelined and non-pipelined
/// replica training follow the same trajectory.
#[test]
fn pipeline_fanout_is_trajectory_neutral() {
    let piped = OptConfig::hifuse();
    let unpiped = OptConfig { pipeline: false, ..piped };
    assert_eq!(
        trajectory(ModelKind::Rgcn, piped, 2, DEFAULT_ROUND),
        trajectory(ModelKind::Rgcn, unpiped, 2, DEFAULT_ROUND),
    );
}

fn run_group_epochs(n: usize, epochs: u64) -> (Vec<ReplicaMetrics>, usize) {
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let n_batches = g.train_idx.len().div_ceil(cfg().batch_size);
    let mut grp =
        ReplicaGroup::new(engines(n), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    ((0..epochs).map(|e| grp.train_epoch(e).unwrap()).collect(), n_batches)
}

/// Per-replica counters (kernel counts, stage breakdowns, arena traffic,
/// cpu time, batch/drop tallies) sum to the group totals.
#[test]
fn per_replica_counters_sum_to_group_totals() {
    let (ms, n_batches) = run_group_epochs(2, 1);
    let m = &ms[0];
    assert_eq!(m.per_replica.len(), 2);
    assert_eq!(m.group.batches, n_batches);
    assert!(m.group.kernels_total > 0);
    // Independent reference (the absorb sums below are true by
    // construction): a single-backend Trainer epoch over the same graph,
    // config, and seed dispatches the same batches with the same plans, so
    // its kernel total must equal the group total.
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    let reference = tr.train_epoch(0).unwrap();
    assert_eq!(m.group.kernels_total, reference.kernels_total);
    assert_eq!(m.group.kernels_fwd_semantic, reference.kernels_fwd_semantic);
    assert_eq!(m.group.kernels_fwd_agg, reference.kernels_fwd_agg);
    let reps = &m.per_replica;
    assert_eq!(m.group.kernels_total, reps.iter().map(|r| r.kernels_total).sum::<usize>());
    assert_eq!(
        m.group.kernels_fwd_semantic,
        reps.iter().map(|r| r.kernels_fwd_semantic).sum::<usize>()
    );
    assert_eq!(m.group.kernels_fwd_agg, reps.iter().map(|r| r.kernels_fwd_agg).sum::<usize>());
    assert_eq!(m.group.batches, reps.iter().map(|r| r.batches).sum::<usize>());
    assert_eq!(m.group.dropped_nodes, reps.iter().map(|r| r.dropped_nodes).sum::<usize>());
    assert_eq!(m.group.dropped_edges, reps.iter().map(|r| r.dropped_edges).sum::<usize>());
    let cpu: std::time::Duration = m.per_replica.iter().map(|r| r.cpu_time).sum();
    assert_eq!(m.group.cpu_time, cpu);
    let gpu: std::time::Duration = m.per_replica.iter().map(|r| r.gpu_time).sum();
    assert_eq!(m.group.gpu_time, gpu);
    let hits: u64 = m.per_replica.iter().map(|r| r.arena.hits).sum();
    let misses: u64 = m.per_replica.iter().map(|r| r.arena.misses).sum();
    assert_eq!(m.group.arena.hits, hits);
    assert_eq!(m.group.arena.misses, misses);
    for (stage, count) in &m.group.kernels_by_stage {
        let per: usize = m
            .per_replica
            .iter()
            .flat_map(|r| r.kernels_by_stage.iter())
            .filter(|(s, _)| s == stage)
            .map(|(_, c)| c)
            .sum();
        assert_eq!(*count, per, "stage {stage:?} mismatch");
    }
    // Both replicas actually worked (the schedule spreads 6 batches).
    assert!(m.per_replica.iter().all(|r| r.kernels_total > 0));
}

/// Each replica's arena reaches steady state: after the warm-up epoch, a
/// further epoch performs zero dispatch allocations on every lane.
#[test]
fn replica_arenas_reach_zero_steady_state_allocations() {
    let (ms, _) = run_group_epochs(2, 3);
    // EpochMetrics.arena is the cumulative snapshot at epoch end: flat
    // misses between epochs 1 and 2 = zero allocations in epoch 2.
    for i in 0..2 {
        let warm = ms[1].per_replica[i].arena;
        let steady = ms[2].per_replica[i].arena;
        assert_eq!(
            steady.misses, warm.misses,
            "replica {i}: steady-state epoch allocated ({warm:?} -> {steady:?})"
        );
        assert!(steady.hits > warm.hits, "replica {i}: arena unused");
    }
}
