//! Runtime-level tests against the built-in `tiny` profile on the default
//! SimBackend: every module in the manifest executes with manifest-shaped
//! inputs and returns manifest-shaped outputs; dispatch accounting and
//! shape checking work. (With `--features pjrt` and AOT artifacts the same
//! contract holds for the PJRT engine — it shares the `ExecBackend` check
//! and accounting paths.)

use hifuse::runtime::{DType, ExecBackend, Phase, SimBackend, Stage};
use hifuse::util::HostTensor;

fn backend() -> SimBackend {
    SimBackend::builtin("tiny").unwrap()
}

fn zero_input(dtype: DType, shape: &[usize]) -> HostTensor {
    match dtype {
        DType::F32 => HostTensor::f32(vec![0.0; shape.iter().product()], shape),
        DType::I32 => HostTensor::i32(vec![0; shape.iter().product()], shape),
    }
}

/// Smoke: every declared module runs and returns tensors whose
/// dtypes/shapes match the manifest. Catches interface drift between the
/// built-in manifest and the interpreter (and, on PJRT, between aot.py and
/// the compiled HLO).
#[test]
fn every_module_roundtrips_interface() {
    let eng = backend();
    let names: Vec<String> = eng.manifest().modules.keys().cloned().collect();
    assert!(names.len() >= 30, "expected full module inventory, got {}", names.len());
    for name in names {
        let spec = eng.manifest().module(&name).unwrap().clone();
        let args: Vec<HostTensor> =
            spec.args.iter().map(|a| zero_input(a.dtype, &a.shape)).collect();
        let refs: Vec<&HostTensor> = args.iter().collect();
        // Leak the name to get a &'static str for the counter tag (test-only).
        let static_name: &'static str = Box::leak(name.clone().into_boxed_str());
        let outs = eng
            .run(static_name, Stage::Calib, Phase::Fwd, &refs)
            .unwrap_or_else(|e| panic!("module {name} failed: {e:#}"));
        assert_eq!(outs.len(), spec.rets.len(), "{name}: return arity");
        for (o, r) in outs.iter().zip(&spec.rets) {
            assert_eq!(o.shape(), r.shape.as_slice(), "{name}: ret shape");
            assert_eq!(o.dtype_str(), r.dtype.name(), "{name}: ret dtype");
        }
    }
}

#[test]
fn shape_mismatch_is_rejected_before_execution() {
    let eng = backend();
    let bad = HostTensor::zeros_f32(&[3, 3]);
    let w = HostTensor::zeros_f32(&[8, 16]);
    let err = eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&bad, &w]).unwrap_err();
    assert!(err.to_string().contains("expects"), "unexpected error: {err}");
}

#[test]
fn dtype_mismatch_is_rejected() {
    let eng = backend();
    let ns = eng.cst("NS");
    let f = eng.cst("F");
    let x_wrong = HostTensor::i32(vec![0; ns * f], &[ns, f]);
    let w = HostTensor::zeros_f32(&[f, eng.cst("H")]);
    assert!(eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x_wrong, &w]).is_err());
}

#[test]
fn wrong_arity_is_rejected() {
    let eng = backend();
    let x = HostTensor::zeros_f32(&[eng.cst("NS"), eng.cst("F")]);
    assert!(eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x]).is_err());
}

#[test]
fn unknown_module_is_an_error() {
    let eng = backend();
    assert!(eng.run("nope", Stage::Calib, Phase::Fwd, &[]).is_err());
}

#[test]
fn projection_computes_matmul() {
    let eng = backend();
    let (ns, f, h) = (eng.cst("NS"), eng.cst("F"), eng.cst("H"));
    // x row 0 = [2,0,...]; w row 0 = 1..h.
    let mut x = vec![0.0f32; ns * f];
    x[0] = 2.0;
    let mut w = vec![0.0f32; f * h];
    for j in 0..h {
        w[j] = (j + 1) as f32;
    }
    let out = eng
        .run(
            "proj_fwd_l0",
            Stage::Calib,
            Phase::Fwd,
            &[&HostTensor::f32(x, &[ns, f]), &HostTensor::f32(w, &[f, h])],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    for j in 0..h {
        assert!((y[j] - 2.0 * (j + 1) as f32).abs() < 1e-5, "y[{j}]={}", y[j]);
    }
    assert!(y[h..].iter().all(|&v| v == 0.0));
}

#[test]
fn merged_aggregation_means_sources() {
    let eng = backend();
    let (ns, ep, rp, h) = (eng.cst("NS"), eng.cst("EP"), eng.cst("RPAD"), eng.cst("H"));
    let mut feat = vec![0.0f32; rp * ns * h];
    // relation 1: rows 2 and 3 hold values 3 and 5 in every column.
    for d in 0..h {
        feat[ns * h + 2 * h + d] = 3.0;
        feat[ns * h + 3 * h + d] = 5.0;
    }
    let mut src = vec![0i32; rp * ep];
    let mut dst = vec![0i32; rp * ep];
    let mut valid = vec![0.0f32; rp * ep];
    // two valid edges in relation 1: 2->7 and 3->7.
    src[ep] = 2;
    dst[ep] = 7;
    valid[ep] = 1.0;
    src[ep + 1] = 3;
    dst[ep + 1] = 7;
    valid[ep + 1] = 1.0;
    let out = eng
        .run(
            "agg_merged_fwd_h",
            Stage::Calib,
            Phase::Fwd,
            &[
                &HostTensor::f32(feat, &[rp, ns, h]),
                &HostTensor::i32(src, &[rp, ep]),
                &HostTensor::i32(dst, &[rp, ep]),
                &HostTensor::f32(valid, &[rp, ep]),
            ],
        )
        .unwrap();
    let a = out[0].as_f32().unwrap();
    for d in 0..h {
        assert!((a[ns * h + 7 * h + d] - 4.0).abs() < 1e-5); // mean(3,5)
    }
    // relation 0 (all invalid) stays zero.
    assert!(a[..ns * h].iter().all(|&v| v == 0.0));
}

#[test]
fn counters_track_dispatches_and_bytes() {
    let eng = backend();
    eng.reset_counters(true);
    let (ns, c) = (eng.cst("NS"), eng.cst("C"));
    let logits = HostTensor::zeros_f32(&[ns, c]);
    let labels = HostTensor::i32(vec![0; ns], &[ns]);
    let mask = HostTensor::f32(vec![1.0; ns], &[ns]);
    eng.run("head", Stage::Head, Phase::Fwd, &[&logits, &labels, &mask]).unwrap();
    let counters = eng.counters().borrow();
    assert_eq!(counters.total(), 1);
    assert_eq!(counters.events.len(), 1);
    let e = &counters.events[0];
    assert_eq!(e.module, "head");
    assert_eq!(e.bytes_in, ns * c * 4 + ns * 4 + ns * 4);
    assert!(e.bytes_out > 0);
    assert!(e.dur.as_nanos() > 0);
}

#[test]
fn dispatch_overhead_probe_is_sane() {
    let eng = backend();
    let us = eng.measure_dispatch_overhead(10).unwrap().as_secs_f64() * 1e6;
    // An interpreted dispatch takes over a tenth of a microsecond and under
    // 100 ms on any machine; anything in that band says the probe works.
    assert!(us > 0.1 && us < 100_000.0, "overhead {us}us");
}

#[test]
fn simulated_launch_overhead_is_applied() {
    let mut eng = backend();
    let base = eng.measure_dispatch_overhead(5).unwrap();
    eng.set_launch_overhead(std::time::Duration::from_micros(500));
    let slow = eng.measure_dispatch_overhead(5).unwrap();
    assert!(slow > base + std::time::Duration::from_micros(300), "{base:?} -> {slow:?}");
}
