//! Device-resident feature-cache regression tests (DESIGN.md §7): the
//! cache is a *transport* optimization, never a semantic one.
//!
//! * Training trajectories are bitwise identical for
//!   `cache-frac ∈ {0, 0.25, 1.0}` × `replicas ∈ {1, 2}` × pipeline
//!   on/off — cached rows are byte-copies of the same f32 data, so the
//!   assembled `[TPAD, NS, F]` slab is the same bytes the CPU gather
//!   produces.
//! * With any hit rate > 0, steady-state H2D bytes per epoch are
//!   **strictly lower** than cache-off (the full slab shipment is replaced
//!   by scatter indices + miss rows only).
//! * The steady state stays allocation-free: backend-arena misses and
//!   producer-pool stats are flat across post-warm-up epochs with the
//!   cache on, same contract as `tests/perf_path.rs` /
//!   `tests/producer_parity.rs`.

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, TrainCfg, Trainer,
    DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};

fn cfg() -> TrainCfg {
    TrainCfg {
        epochs: 1,
        batch_size: 4,
        fanout: 3,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers: 2,
    }
}

fn store_for(g: &hifuse::graph::HeteroGraph, frac: f64) -> Arc<ResidentStore> {
    Arc::new(ResidentStore::build(g, frac, 160, 42))
}

/// Single-backend trajectory over 3 epochs for a cache fraction.
fn trainer_trajectory(model: ModelKind, pipeline: bool, frac: f64) -> Vec<(f64, f64)> {
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
    if frac > 0.0 {
        tr.attach_cache(store_for(&g, frac)).unwrap();
    }
    (0..3)
        .map(|e| {
            let m = tr.train_epoch(e).unwrap();
            (m.loss, m.acc)
        })
        .collect()
}

/// Replica-group trajectory over 2 epochs.
fn replica_trajectory(replicas: usize, pipeline: bool, frac: f64) -> Vec<(f64, f64)> {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let t = replica_thread_budget(4, replicas);
    let engines: Vec<SimBackend> =
        (0..replicas).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    if frac > 0.0 {
        grp.attach_cache(store_for(&g, frac)).unwrap();
    }
    (0..2)
        .map(|e| {
            let m = grp.train_epoch(e).unwrap();
            (m.group.loss, m.group.acc)
        })
        .collect()
}

/// The headline contract: the full issue grid — cache-frac {0, 0.25, 1.0}
/// × replicas {1, 2} × pipeline on/off — follows one bitwise trajectory.
#[test]
fn cache_frac_never_changes_the_trajectory() {
    // Single-backend paths, both models.
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let reference = trainer_trajectory(model, false, 0.0);
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25, 1.0] {
                let t = trainer_trajectory(model, pipeline, frac);
                assert_eq!(
                    t,
                    reference,
                    "{}: frac {frac} pipeline {pipeline} diverged",
                    model.name()
                );
            }
        }
    }
    // Replica paths (their round semantics differ from per-batch SGD, so
    // they have their own reference).
    let reference = replica_trajectory(1, false, 0.0);
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25, 1.0] {
                let t = replica_trajectory(replicas, pipeline, frac);
                assert_eq!(
                    t, reference,
                    "replicas={replicas} pipeline={pipeline} frac={frac} diverged"
                );
            }
        }
    }
}

/// The same invariance holds on the device-resident path (`--mode
/// resident`, DESIGN.md §7), where the gather output feeds the stacked
/// projection as a `DevBuf` instead of materializing to host: cache-frac
/// {0, 0.25, 1.0} follow one bitwise trajectory, which also equals the
/// host-staged trajectory (the cross-plan half lives in
/// `tests/residency.rs`).
#[test]
fn cache_frac_never_changes_the_resident_trajectory() {
    let resident = |model: ModelKind, pipeline: bool, frac: f64| -> Vec<(f64, f64)> {
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let opt = OptConfig { pipeline, ..OptConfig::resident() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
        if frac > 0.0 {
            tr.attach_cache(store_for(&g, frac)).unwrap();
        }
        (0..3)
            .map(|e| {
                let m = tr.train_epoch(e).unwrap();
                (m.loss, m.acc)
            })
            .collect()
    };
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let reference = resident(model, false, 0.0);
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25, 1.0] {
                let t = resident(model, pipeline, frac);
                assert_eq!(
                    t,
                    reference,
                    "{}: resident frac {frac} pipeline {pipeline} diverged",
                    model.name()
                );
            }
        }
    }
}

/// Steady-state H2D bytes per epoch are strictly lower with the cache on,
/// and the hit rate is positive on the builtin tiny manifest; a full cache
/// misses nothing after the resident store is pinned.
#[test]
fn cache_cuts_h2d_bytes_with_positive_hit_rate() {
    let run = |frac: f64| -> (u64, u64, u64) {
        let eng = SimBackend::builtin("tiny").unwrap();
        let opt = OptConfig { pipeline: false, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        if frac > 0.0 {
            tr.attach_cache(store_for(&g, frac)).unwrap();
        }
        tr.train_epoch(0).unwrap(); // warm up
        let m = tr.train_epoch(1).unwrap(); // steady-state epoch
        (m.h2d_bytes, m.cache_hits, m.cache_misses)
    };
    let (off_h2d, off_hits, off_misses) = run(0.0);
    assert_eq!((off_hits, off_misses), (0, 0), "cache-off recorded cache traffic");
    for frac in [0.25f64, 1.0] {
        let (on_h2d, hits, misses) = run(frac);
        assert!(hits > 0, "frac {frac}: no cache hits on the tiny manifest");
        assert!(
            on_h2d < off_h2d,
            "frac {frac}: h2d did not shrink ({on_h2d} vs {off_h2d})"
        );
        if frac == 1.0 {
            assert_eq!(misses, 0, "full cache still missed");
        }
    }
    // More cache ⇒ no more H2D: the fractions order monotonically.
    let (quarter, _, _) = run(0.25);
    let (full, _, _) = run(1.0);
    assert!(full <= quarter, "frac 1.0 moved more bytes than 0.25");
}

/// The cache path keeps the zero-allocation steady state: backend-arena
/// misses and producer-pool stats are flat after warm-up (the gather
/// output and the recycled slab swap through the arena every batch).
#[test]
fn cache_path_reaches_zero_steady_state_allocations() {
    for pipeline in [false, true] {
        let eng = SimBackend::builtin_threaded("tiny", 2).unwrap();
        let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        tr.attach_cache(store_for(&g, 0.25)).unwrap();
        tr.train_epoch(0).unwrap();
        let warm = tr.train_epoch(1).unwrap();
        let steady = tr.train_epoch(2).unwrap();
        assert_eq!(
            steady.arena.misses, warm.arena.misses,
            "pipeline {pipeline}: steady-state dispatch allocated \
             ({:?} -> {:?})",
            warm.arena, steady.arena
        );
        assert_eq!(
            steady.producer.fresh, warm.producer.fresh,
            "pipeline {pipeline}: steady state constructed a buffer set"
        );
        assert_eq!(
            steady.producer.grown, warm.producer.grown,
            "pipeline {pipeline}: steady state grew a pooled buffer"
        );
        assert!(steady.producer.reused > warm.producer.reused);
    }
}

/// Replica groups report cache traffic per lane and in the group totals,
/// and every lane hits (the store is shared, the handles per-backend).
#[test]
fn replica_lanes_share_the_store_and_count_cache_traffic() {
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let t = replica_thread_budget(4, 2);
    let engines: Vec<SimBackend> =
        (0..2).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    let store = store_for(&g, 0.5);
    grp.attach_cache(store.clone()).unwrap();
    assert!(Arc::ptr_eq(grp.cache_store().unwrap(), &store), "store not shared");
    let m = grp.train_epoch(0).unwrap();
    for (i, r) in m.per_replica.iter().enumerate() {
        assert!(r.cache_hits > 0, "lane {i} never hit the shared store");
    }
    let lane_hits: u64 = m.per_replica.iter().map(|r| r.cache_hits).sum();
    let lane_misses: u64 = m.per_replica.iter().map(|r| r.cache_misses).sum();
    assert_eq!(m.group.cache_hits, lane_hits);
    assert_eq!(m.group.cache_misses, lane_misses);
    assert!(m.group.cache_hit_rate() > 0.0);
}

/// Attaching a cache mid-run is rejected: recycled buffer sets are sized
/// for the active collection mode.
#[test]
fn late_attach_is_rejected() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let opt = OptConfig { pipeline: false, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    tr.train_epoch(0).unwrap();
    assert!(tr.attach_cache(store_for(&g, 0.5)).is_err(), "late attach must fail");
    // And double attach too.
    let mut tr2 = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    tr2.attach_cache(store_for(&g, 0.5)).unwrap();
    assert!(tr2.attach_cache(store_for(&g, 0.5)).is_err(), "double attach must fail");
    // The replica group enforces the same contract (a late attach would
    // otherwise hand uncached recycled buffer sets to the split).
    let engines: Vec<SimBackend> =
        (0..2).map(|_| SimBackend::builtin_threaded("tiny", 2).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    grp.train_epoch(0).unwrap();
    assert!(grp.attach_cache(store_for(&g, 0.5)).is_err(), "replica late attach must fail");
}

/// The gather dispatch is visible in the counters: exactly one
/// `collection`-stage dispatch per batch with the cache on, zero off.
#[test]
fn gather_dispatch_counts_one_per_batch() {
    use hifuse::runtime::{Phase, Stage};
    let run = |frac: f64| -> (usize, usize) {
        let eng = SimBackend::builtin("tiny").unwrap();
        let opt = OptConfig { pipeline: false, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        if frac > 0.0 {
            tr.attach_cache(store_for(&g, frac)).unwrap();
        }
        let m = tr.train_epoch(0).unwrap();
        let c = eng.counters().borrow();
        (c.count_phase(Stage::Collection, Phase::Fwd), m.batches)
    };
    let (off, _) = run(0.0);
    assert_eq!(off, 0);
    let (on, batches) = run(0.5);
    assert_eq!(on, batches, "expected one feature_gather per batch");
}
