//! Hot-path overhaul regression tests: the dispatch buffer arena reaches
//! zero steady-state allocations, and kernel threading never changes a
//! single bit of the training trajectory.

use hifuse::coordinator::{prepare_graph_layout, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::SimBackend;

fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 8, fanout: 3, lr: 0.05, seed: 42, threads: 2, producers: 0 }
}

/// After one warm-up epoch every buffer class the step needs is pooled, so
/// a further epoch performs zero dispatch allocations (arena misses flat).
#[test]
fn arena_steady_state_allocations_per_step_are_zero() {
    for (model, mode) in [
        (ModelKind::Rgcn, "hifuse"),
        (ModelKind::Rgat, "hifuse"),
        (ModelKind::Rgcn, "base"),
        (ModelKind::Rgcn, "hifuse+stacked"),
    ] {
        let eng = SimBackend::builtin("tiny").unwrap();
        let opt = OptConfig::parse(mode).unwrap();
        let mut g = tiny_graph(5);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
        tr.train_epoch(0).unwrap(); // warm-up fills the arena
        let warm = eng.arena_stats();
        tr.train_epoch(1).unwrap();
        let steady = eng.arena_stats();
        assert_eq!(
            steady.misses, warm.misses,
            "{} {mode}: steady-state epoch allocated ({warm:?} -> {steady:?})",
            model.name()
        );
        assert!(steady.hits > warm.hits, "{} {mode}: arena unused", model.name());
    }
}

/// Kernel row-parallelism is partition-only: the training trajectory on a
/// 4-thread backend is bit-identical to the serial backend, for both
/// models and with the stacked-projection extension.
#[test]
fn threaded_kernels_are_bit_identical_to_serial() {
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        for mode in ["hifuse", "hifuse+stacked", "base"] {
            let losses = |threads: usize| -> Vec<f64> {
                let eng = SimBackend::builtin_threaded("tiny", threads).unwrap();
                let opt = OptConfig::parse(mode).unwrap();
                let mut g = tiny_graph(1);
                prepare_graph_layout(&mut g, &opt);
                let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
                (0..2).map(|e| tr.train_epoch(e).unwrap().loss).collect()
            };
            let serial = losses(1);
            let threaded = losses(4);
            assert_eq!(
                serial,
                threaded,
                "{} {mode}: thread count changed the trajectory",
                model.name()
            );
        }
    }
}
