//! Data-integrity matrix (DESIGN.md §11): silent corruption is injected
//! at schedule-addressed sites (`flip!` feature payloads, `nan!`
//! gradients/logits, `wire!` transfers) and the guard/audit plane must
//! detect and repair it *bitwise*. Across the grid
//! {site × train/serve × replicas {1, 2} × pipeline on/off × cache-frac
//! {0, 0.25}}:
//!
//! * a guarded-but-clean run is bitwise identical to an unguarded one,
//!   with the same kernel count — detection adds zero dispatches;
//! * every injected corruption under the guard is detected and counted
//!   exactly: one violation per firing, recompute first, rollback+replay
//!   on persistence, a typed error past the budget;
//! * both recovery tiers converge bitwise to the fault-free trajectory
//!   (re-derivation from `(epoch_perm, seq)` is why this is possible);
//! * the same corruptions *without* the guard are silent — zero counters
//!   — and (where the payload is live) visibly diverge: the divergence
//!   witness that proves the guard is load-bearing;
//! * serve lanes recompute guarded violations, and repeat offenders feed
//!   the §10 quarantine plane as suspects on the next drive;
//! * recovery preserves the zero-allocation steady state.

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, ChurnStats, EpochMetrics, OptConfig,
    ReplicaGroup, ReplicaMetrics, TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::graph::HeteroGraph;
use hifuse::models::{ModelKind, Params};
use hifuse::runtime::{ResidentStore, SimBackend};
use hifuse::serving::{self, ServeOptions, Trace};
use hifuse::util::{FaultPlan, FaultSite};

/// 6 batches/epoch on tiny's 24 train seeds (audit cadence math below
/// depends on this).
fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 4, fanout: 3, lr: 0.05, seed: 42, threads: 4, producers: 2 }
}

fn plan(spec: &str) -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse(spec, 0).unwrap())
}

fn assert_params_eq(a: &Params, b: &Params, ctx: &str) {
    assert_eq!(a.w0, b.w0, "{ctx}: w0 diverged");
    assert_eq!(a.w1, b.w1, "{ctx}: w1 diverged");
    assert_eq!(a.a_src0, b.a_src0, "{ctx}: a_src0 diverged");
    assert_eq!(a.a_dst0, b.a_dst0, "{ctx}: a_dst0 diverged");
    assert_eq!(a.a_src1, b.a_src1, "{ctx}: a_src1 diverged");
    assert_eq!(a.a_dst1, b.a_dst1, "{ctx}: a_dst1 diverged");
}

fn params_differ(a: &Params, b: &Params) -> bool {
    a.w0 != b.w0
        || a.w1 != b.w1
        || a.a_src0 != b.a_src0
        || a.a_dst0 != b.a_dst0
        || a.a_src1 != b.a_src1
        || a.a_dst1 != b.a_dst1
}

/// One single-backend run with the integrity plane configured; returns
/// the trajectory, final params, and every epoch's metrics.
fn run_trainer(
    pipeline: bool,
    frac: f64,
    guard: bool,
    audit_every: u64,
    spec: Option<&str>,
    epochs: u64,
) -> (Vec<(f64, f64)>, Params, Vec<EpochMetrics>) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    if frac > 0.0 {
        tr.attach_cache(Arc::new(ResidentStore::build(&g, frac, 160, 42))).unwrap();
    }
    if let Some(s) = spec {
        tr.set_fault_plan(plan(s));
    }
    tr.set_guard(guard).unwrap();
    tr.set_audit_every(audit_every).unwrap();
    let ms: Vec<EpochMetrics> = (0..epochs).map(|e| tr.train_epoch(e).unwrap()).collect();
    let traj = ms.iter().map(|m| (m.loss, m.acc)).collect();
    (traj, tr.params.clone(), ms)
}

/// `true` iff epoch 0 of the configured run errors (budget-exhaustion
/// cases: corruption must be a typed failure, never a wrong answer).
fn trainer_epoch0_errs(pipeline: bool, guard: bool, audit_every: u64, spec: &str) -> bool {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    tr.set_fault_plan(plan(spec));
    tr.set_guard(guard).unwrap();
    tr.set_audit_every(audit_every).unwrap();
    tr.train_epoch(0).is_err()
}

/// (violations, retransmits, recomputes, rollbacks, audits) summed over
/// the run.
fn isum(ms: &[EpochMetrics]) -> (u64, u64, u64, u64, u64) {
    (
        ms.iter().map(|m| m.integrity_violations).sum(),
        ms.iter().map(|m| m.integrity_retransmits).sum(),
        ms.iter().map(|m| m.integrity_recomputes).sum(),
        ms.iter().map(|m| m.integrity_rollbacks).sum(),
        ms.iter().map(|m| m.audits).sum(),
    )
}

fn engines(n: usize) -> Vec<SimBackend> {
    let t = replica_thread_budget(4, n);
    (0..n).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect()
}

/// Replica-group analog of [`run_trainer`].
fn run_group(
    replicas: usize,
    pipeline: bool,
    guard: bool,
    audit_every: u64,
    spec: Option<&str>,
    epochs: u64,
) -> (Vec<(f64, f64)>, Params, Vec<ReplicaMetrics>) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
            .unwrap();
    if let Some(s) = spec {
        grp.set_fault_plan(plan(s));
    }
    grp.set_guard(guard).unwrap();
    grp.set_audit_every(audit_every).unwrap();
    let ms: Vec<ReplicaMetrics> = (0..epochs).map(|e| grp.train_epoch(e).unwrap()).collect();
    let traj = ms.iter().map(|m| (m.group.loss, m.group.acc)).collect();
    (traj, grp.params.clone(), ms)
}

fn group_epoch0_errs(replicas: usize, guard: bool, audit_every: u64, spec: &str) -> bool {
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
            .unwrap();
    grp.set_fault_plan(plan(spec));
    grp.set_guard(guard).unwrap();
    grp.set_audit_every(audit_every).unwrap();
    grp.train_epoch(0).is_err()
}

/// The headline invisibility contract: arming the guard on a clean run
/// changes *nothing* — bitwise trajectory and parameters, identical
/// kernel counts (zero added dispatches), zero integrity counters on both
/// sides — across pipeline, cache, and replica cells.
#[test]
fn guard_on_a_clean_run_is_bitwise_invisible_and_dispatch_neutral() {
    for pipeline in [false, true] {
        for frac in [0.0f64, 0.25] {
            let ctx = format!("pipeline={pipeline} frac={frac}");
            let (base_t, base_p, base_ms) = run_trainer(pipeline, frac, false, 0, None, 2);
            assert_eq!(isum(&base_ms), (0, 0, 0, 0, 0), "{ctx}: default-off run counted");
            let (t, p, ms) = run_trainer(pipeline, frac, true, 0, None, 2);
            assert_eq!(t, base_t, "{ctx}: guarded trajectory diverged");
            assert_params_eq(&p, &base_p, &ctx);
            assert_eq!(isum(&ms), (0, 0, 0, 0, 0), "{ctx}: clean guarded run counted");
            for (e, (gm, bm)) in ms.iter().zip(&base_ms).enumerate() {
                assert_eq!(
                    gm.kernels_total, bm.kernels_total,
                    "{ctx} epoch {e}: the guard added dispatches"
                );
            }
        }
    }
    for (replicas, pipeline) in [(1usize, false), (2, false), (2, true)] {
        let ctx = format!("replicas={replicas} pipeline={pipeline}");
        let (base_t, base_p, base_ms) = run_group(replicas, pipeline, false, 0, None, 2);
        let (t, p, ms) = run_group(replicas, pipeline, true, 0, None, 2);
        assert_eq!(t, base_t, "{ctx}: guarded group trajectory diverged");
        assert_params_eq(&p, &base_p, &ctx);
        for (e, (gm, bm)) in ms.iter().zip(&base_ms).enumerate() {
            assert_eq!(gm.group.integrity_violations, 0, "{ctx} epoch {e}");
            assert_eq!(gm.group.integrity_recomputes, 0, "{ctx} epoch {e}");
            assert_eq!(
                gm.group.kernels_total, bm.group.kernels_total,
                "{ctx} epoch {e}: the guard added dispatches"
            );
        }
    }
}

/// Audit-only runs (no guard, no faults) are pure metrology: bitwise
/// parity with the classic loop, audits counted at exactly the cadence
/// boundaries, nothing else moves. 6 batches at `--audit-every 2` =
/// audits after batches 1, 3, 5 (the last doubling as the epoch-end
/// audit); a 2-replica group at cadence 4 audits at round boundaries
/// `done = 4` and `done = 6`.
#[test]
fn audit_only_runs_are_parity_and_counted_at_the_cadence() {
    for pipeline in [false, true] {
        for frac in [0.0f64, 0.25] {
            let ctx = format!("pipeline={pipeline} frac={frac}");
            let (base_t, base_p, _) = run_trainer(pipeline, frac, false, 0, None, 2);
            let (t, p, ms) = run_trainer(pipeline, frac, false, 2, None, 2);
            assert_eq!(t, base_t, "{ctx}: audited trajectory diverged");
            assert_params_eq(&p, &base_p, &ctx);
            assert_eq!(isum(&ms), (0, 0, 0, 0, 6), "{ctx}: 3 audits per epoch");
            for (e, m) in ms.iter().enumerate() {
                assert_eq!(m.audits, 3, "{ctx} epoch {e}: audit cadence");
            }
        }
    }
    let (base_t, base_p, _) = run_group(2, false, false, 0, None, 2);
    let (t, p, ms) = run_group(2, false, false, 4, None, 2);
    assert_eq!(t, base_t, "audited group trajectory diverged");
    assert_params_eq(&p, &base_p, "group audit-only");
    let audits: u64 = ms.iter().map(|m| m.group.audits).sum();
    assert_eq!(audits, 4, "2 round-boundary audits per epoch");
}

/// The guarded `flip!` ladder, with exact accounting at every rung: one
/// firing is caught by the feature digest and recomputed; a second firing
/// of the same address survives the recompute, forcing rollback + replay;
/// a third exhausts the budget into a typed error. Rungs one and two land
/// bitwise on the fault-free run.
#[test]
fn guarded_flip_recomputes_then_rolls_back_then_bails() {
    for pipeline in [false, true] {
        let ctx = format!("pipeline={pipeline}");
        let (base_t, base_p, _) = run_trainer(pipeline, 0.0, false, 0, None, 1);
        let (t, p, ms) = run_trainer(pipeline, 0.0, true, 0, Some("flip!@0:2"), 1);
        assert_eq!(t, base_t, "{ctx}: recomputed run diverged");
        assert_params_eq(&p, &base_p, &format!("{ctx} flip x1"));
        assert_eq!(isum(&ms), (1, 0, 1, 0, 0), "{ctx}: one flip = one recompute");
        let (t, p, ms) = run_trainer(pipeline, 0.0, true, 0, Some("flip!@0:2x2"), 1);
        assert_eq!(t, base_t, "{ctx}: rolled-back run diverged");
        assert_params_eq(&p, &base_p, &format!("{ctx} flip x2"));
        assert_eq!(isum(&ms), (2, 0, 1, 1, 0), "{ctx}: persistent flip escalates");
    }
    assert!(
        trainer_epoch0_errs(false, true, 0, "flip!@0:2x3"),
        "a flip outliving recompute and rollback must be a typed error"
    );
}

/// The same corruption without the guard is *silent*: zero integrity
/// counters, and the run walks off the fault-free trajectory — the
/// divergence witness that makes the guard's parity meaningful.
#[test]
fn unguarded_flip_diverges_silently() {
    let (_, base_p, _) = run_trainer(false, 0.0, false, 0, None, 1);
    let (_, p, ms) = run_trainer(false, 0.0, false, 0, Some("flip!~1"), 1);
    assert_eq!(isum(&ms), (0, 0, 0, 0, 0), "unguarded corruption must count nothing");
    assert!(params_differ(&p, &base_p), "an unguarded flip sprinkle must diverge");
}

/// `flip!` against the resident feature cache: corrupted *miss* payloads
/// are caught by the same digest and recomputed, bitwise — one violation
/// and one recompute per firing batch that actually had misses.
#[test]
fn guarded_flip_recovers_through_the_cache_path() {
    let (base_t, base_p, _) = run_trainer(false, 0.25, false, 0, None, 1);
    let (t, p, ms) = run_trainer(false, 0.25, true, 0, Some("flip!~1"), 1);
    assert_eq!(t, base_t, "cached guarded flips diverged");
    assert_params_eq(&p, &base_p, "cache-frac 0.25 flip sprinkle");
    let (v, rt, r, rb, _) = isum(&ms);
    assert!(v >= 1, "the sprinkle must land on at least one miss payload");
    assert_eq!((v, rt, rb), (r, 0, 0), "every cached violation is one recompute");
}

/// `nan!` in the gradients: the guard's pre-apply finite scan catches it
/// and recomputes; without the guard the poison reaches the parameters
/// and only the periodic digest audit can see it — rollback to the last
/// good snapshot and replay forward, still bitwise. Past the replay
/// budget it's a typed error.
#[test]
fn nan_is_caught_pre_apply_or_rolled_back_by_the_audit() {
    for pipeline in [false, true] {
        let ctx = format!("pipeline={pipeline}");
        let (base_t, base_p, _) = run_trainer(pipeline, 0.0, false, 0, None, 1);
        let (t, p, ms) = run_trainer(pipeline, 0.0, true, 0, Some("nan!@0:3"), 1);
        assert_eq!(t, base_t, "{ctx}: guarded nan run diverged");
        assert_params_eq(&p, &base_p, &format!("{ctx} guarded nan"));
        assert_eq!(isum(&ms), (1, 0, 1, 0, 0), "{ctx}: pre-apply catch is a recompute");
    }
    // Unguarded: the audit at batch 3's cadence boundary finds non-finite
    // params, rolls back to the batch-1 snapshot, and replays; the
    // re-fired injection costs a second rollback before converging.
    let (base_t, base_p, _) = run_trainer(false, 0.0, false, 0, None, 1);
    let (t, p, ms) = run_trainer(false, 0.0, false, 2, Some("nan!@0:3x2"), 1);
    assert_eq!(t, base_t, "audit-recovered nan run diverged");
    assert_params_eq(&p, &base_p, "unguarded nan + audit");
    assert_eq!(isum(&ms), (2, 0, 0, 2, 3), "audit rollback accounting");
    assert!(
        trainer_epoch0_errs(false, false, 2, "nan!@0:3x3"),
        "nan outliving both replays must be a typed error"
    );
}

/// `wire!` on the H2D path. Guarded, the backend verifies the payload at
/// delivery and retransmits clean — violations == retransmits == the
/// plan's multiplicity, zero recomputes, bitwise parity — and a burst
/// past the retry budget bails. Unguarded with the cache attached the
/// corrupt miss payload silently diverges; unguarded *without* the cache
/// it lands in the accounting-only staging copy (the batch computes from
/// host features), which the §11 docs call out as the one dead site.
#[test]
fn wire_corruption_is_retransmitted_or_silently_diverges() {
    for frac in [0.0f64, 0.25] {
        let ctx = format!("frac={frac}");
        let (base_t, base_p, _) = run_trainer(false, frac, false, 0, None, 1);
        let (t, p, ms) = run_trainer(false, frac, true, 0, Some("wire!@0:2x2"), 1);
        assert_eq!(t, base_t, "{ctx}: retransmitted run diverged");
        assert_params_eq(&p, &base_p, &format!("{ctx} guarded wire"));
        let (v, rt, r, rb, _) = isum(&ms);
        assert_eq!((v, rt, r, rb), (2, 2, 0, 0), "{ctx}: retransmit accounting");
    }
    assert!(
        trainer_epoch0_errs(false, true, 0, "wire!@0:2x4"),
        "a wire burst past the retransmit budget must be a typed error"
    );
    // Divergence witness: live (cached) payload, no guard.
    let (_, base_p, _) = run_trainer(false, 0.25, false, 0, None, 1);
    let (_, p, ms) = run_trainer(false, 0.25, false, 0, Some("wire!~1"), 1);
    assert_eq!(isum(&ms), (0, 0, 0, 0, 0), "unguarded wire must count nothing");
    assert!(params_differ(&p, &base_p), "unguarded cached wire corruption must diverge");
    // Dead site: cache off, the corrupted upload is staging-only.
    let (base_t, base_p, _) = run_trainer(false, 0.0, false, 0, None, 1);
    let (t, p, ms) = run_trainer(false, 0.0, false, 0, Some("wire!@0:2"), 1);
    assert_eq!(isum(&ms), (0, 0, 0, 0, 0));
    assert_eq!(t, base_t, "cache-off wire must be trajectory-neutral");
    assert_params_eq(&p, &base_p, "cache-off wire hits the discarded staging copy");
}

/// Integrity recovery preserves the zero-allocation steady state: with a
/// guarded flip recomputed in the warm-up epoch *and* in a post-warm-up
/// epoch, the recovery epoch still never misses the arena.
#[test]
fn integrity_recovery_keeps_the_zero_alloc_steady_state() {
    let (base_t, base_p, _) = run_trainer(false, 0.0, false, 0, None, 4);
    let (t, p, ms) = run_trainer(false, 0.0, true, 0, Some("flip!@0:2,flip!@3:3"), 4);
    assert_eq!(t, base_t, "steady-state integrity run diverged");
    assert_params_eq(&p, &base_p, "steady-state integrity run");
    assert_eq!(ms[0].integrity_recomputes, 1, "warm-up epoch recompute");
    assert_eq!(ms[3].integrity_recomputes, 1, "steady-state epoch recompute");
    assert_eq!(
        ms[3].arena.misses, ms[2].arena.misses,
        "recovery epoch allocated ({:?} -> {:?})",
        ms[2].arena, ms[3].arena
    );
    assert!(ms[3].arena.hits > ms[2].arena.hits, "arena unused");
}

/// Replica lanes guard their own batches: a lane-side flip is recomputed
/// on the lane before its gradients enter the round merge, the counters
/// roll up per-lane → group, and a flip surviving the lane's recompute is
/// a typed error (lanes have no rollback tier — the group audit does).
#[test]
fn replica_lane_guard_recovers_and_rolls_up() {
    for replicas in [1usize, 2] {
        let ctx = format!("replicas={replicas}");
        let (base_t, base_p, _) = run_group(replicas, false, false, 0, None, 1);
        let (t, p, ms) = run_group(replicas, false, true, 0, Some("flip!@0:1"), 1);
        assert_eq!(t, base_t, "{ctx}: lane-recovered trajectory diverged");
        assert_params_eq(&p, &base_p, &ctx);
        let m = &ms[0];
        assert_eq!(m.group.integrity_violations, 1, "{ctx}: violation accounting");
        assert_eq!(m.group.integrity_recomputes, 1, "{ctx}: recompute accounting");
        assert_eq!(m.group.integrity_rollbacks, 0, "{ctx}: no rollback tier on lanes");
        let per: u64 = m.per_replica.iter().map(|r| r.integrity_recomputes).sum();
        assert_eq!(m.group.integrity_recomputes, per, "{ctx}: per-lane rollup");
    }
    assert!(
        group_epoch0_errs(2, true, 0, "flip!@0:1x2"),
        "a flip surviving the lane recompute must be a typed error"
    );
}

/// The group-level audit tier: an unguarded `nan!` poisons the merged
/// parameters; the round-boundary digest audit detects it, rolls the
/// group back to the last good round snapshot, and replays the rounds in
/// merge order — bitwise. Outliving both replays is a typed error.
#[test]
fn replica_group_audit_rolls_back_poisoned_rounds() {
    let (base_t, base_p, _) = run_group(2, false, false, 0, None, 1);
    let (t, p, ms) = run_group(2, false, false, 4, Some("nan!@0:1x2"), 1);
    assert_eq!(t, base_t, "group-rollback trajectory diverged");
    assert_params_eq(&p, &base_p, "group audit rollback");
    let m = &ms[0];
    assert_eq!(m.group.integrity_violations, 2, "violation accounting");
    assert_eq!(m.group.integrity_rollbacks, 2, "rollback accounting");
    assert_eq!(m.group.integrity_recomputes, 0, "no lane guard in this run");
    assert_eq!(m.group.audits, 2, "round-boundary audit cadence");
    assert!(
        group_epoch0_errs(2, false, 4, "nan!@0:1x3"),
        "nan outliving both group replays must be a typed error"
    );
}

// ---------------------------------------------------------------- serve --

const WINDOW: u64 = 2_000;

/// Open-loop trace of 24 requests — a dozen-odd coalesced batches, enough
/// to outlast a probation cycle.
fn test_trace() -> Trace {
    serving::trace::generate(&tiny_graph(1), 42, 1000.0, 24, 3)
}

fn serve_group<'g>(
    g: &'g HeteroGraph,
    replicas: usize,
    pipeline: bool,
    guard: bool,
    spec: Option<&str>,
) -> ReplicaGroup<'g, SimBackend> {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut grp =
        ReplicaGroup::new(engines(replicas), g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
            .unwrap();
    if let Some(s) = spec {
        grp.set_fault_plan(plan(s));
    }
    grp.set_guard(guard).unwrap();
    grp
}

/// Serve-side guard: non-finite logits are caught and the batch is
/// recomputed on its lane, bitwise; a lane that does it twice in one
/// drive is branded *suspect*, and the next drive on the same group
/// starts it pre-quarantined (probation shadowing, then re-admission) —
/// the §11 → §10 closed loop. The injections re-fire on the re-routed
/// batches, branding the surviving lane in turn.
#[test]
fn serve_guard_recomputes_and_suspects_feed_the_quarantine_loop() {
    let trace = test_trace();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &OptConfig::hifuse());
    let mut refg = serve_group(&g, 2, false, false, None);
    let reference = serving::serve_churn(
        &mut refg,
        &trace,
        cfg().batch_size,
        WINDOW,
        &ServeOptions::quiescent(),
    )
    .unwrap();
    assert!(reference.churn.is_quiet());
    assert!(reference.suspect_lanes.is_empty());
    assert!(reference.batches.len() >= 5, "trace must outlast a probation cycle");

    // Drive 1: both injections land on lane 0 (batches 0 and 2 of the
    // all-healthy bi % 2 rotation) — two guarded violations brand it.
    let mut grp = serve_group(&g, 2, false, true, Some("nan!@0:0,nan!@0:2"));
    let opts = ServeOptions::quiescent();
    let d1 = serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &opts).unwrap();
    assert_eq!(d1.predictions, reference.predictions, "guarded serve diverged");
    assert_eq!(
        d1.churn,
        ChurnStats { integrity_violations: 2, integrity_recomputes: 2, ..ChurnStats::default() },
        "drive 1 accounting"
    );
    assert_eq!(d1.suspect_lanes, vec![0], "twice-violating lane 0 must be suspect");

    // Drive 2, same group: lane 0 starts quarantined (counted, not
    // re-dispatched), shadows its probation, and re-enters at batch 4.
    // The injected batches re-route to lane 1 — which now takes both
    // violations and becomes the next suspect.
    let d2 = serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &opts).unwrap();
    assert_eq!(d2.predictions, reference.predictions, "pre-quarantined serve diverged");
    assert_eq!(
        d2.churn,
        ChurnStats {
            lane_quarantines: 1,
            lane_readmissions: 1,
            shadow_batches: 2, // DEFAULT_PROBATION
            integrity_violations: 2,
            integrity_recomputes: 2,
            ..ChurnStats::default()
        },
        "drive 2 accounting"
    );
    assert_eq!(d2.suspect_lanes, vec![1], "re-routed injections brand lane 1");

    // Drive 3: the loop keeps closing — lane 1 pre-quarantined now.
    let d3 = serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &opts).unwrap();
    assert_eq!(d3.predictions, reference.predictions, "drive 3 diverged");
    assert_eq!(d3.churn.lane_quarantines, 1);
}

/// The serve-side guard composes with pipelined lanes: a single guarded
/// `nan!` recomputes on its lane with exact accounting and no suspects.
#[test]
fn serve_guard_parity_holds_with_pipeline_lanes() {
    let trace = test_trace();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &OptConfig { pipeline: true, ..OptConfig::hifuse() });
    let mut refg = serve_group(&g, 2, true, false, None);
    let reference = serving::serve_churn(
        &mut refg,
        &trace,
        cfg().batch_size,
        WINDOW,
        &ServeOptions::quiescent(),
    )
    .unwrap();
    let mut grp = serve_group(&g, 2, true, true, Some("nan!@0:1"));
    let out = serving::serve_churn(
        &mut grp,
        &trace,
        cfg().batch_size,
        WINDOW,
        &ServeOptions::quiescent(),
    )
    .unwrap();
    assert_eq!(out.predictions, reference.predictions, "pipelined guarded serve diverged");
    assert_eq!(
        out.churn,
        ChurnStats { integrity_violations: 1, integrity_recomputes: 1, ..ChurnStats::default() }
    );
    assert!(out.suspect_lanes.is_empty(), "one violation must not brand a lane");
}

// ----------------------------------------------------------- guard rails --

/// The integrity plane refuses the fused device-resident step up front
/// (its single SGD module cannot split the check from the apply); turning
/// the plane *off* is always accepted.
#[test]
fn integrity_setters_reject_the_fused_resident_step() {
    let opt = OptConfig { stacked_proj: true, dev_resident: true, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    assert!(tr.set_guard(true).is_err());
    assert!(tr.set_audit_every(2).is_err());
    assert!(tr.set_guard(false).is_ok());
    assert!(tr.set_audit_every(0).is_ok());
    let mut grp =
        ReplicaGroup::new(engines(2), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    assert!(grp.set_guard(true).is_err());
    assert!(grp.set_audit_every(4).is_err());
    assert!(grp.set_guard(false).is_ok());
}

/// Every fault site — crash and corruption alike — is documented where
/// operators look for it: the README grammar table and flag docs. The
/// spec-grammar round-trip itself is pinned in `util/fault.rs` unit
/// tests; this guards the human-facing half.
#[test]
fn readme_documents_every_site_and_integrity_flag() {
    let readme = include_str!("../../README.md");
    for site in FaultSite::ALL {
        assert!(
            readme.contains(site.name()),
            "README fault grammar table is missing `{}`",
            site.name()
        );
    }
    for needle in ["--guard", "--audit-every", "verify-ckpt", "--fault-spec"] {
        assert!(readme.contains(needle), "README is missing `{needle}`");
    }
}
