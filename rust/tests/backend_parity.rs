//! Backend parity: the SimBackend's *measured* per-step kernel counts
//! equal `plan::expected_counts` for **every** ablation-ladder mode (base,
//! R, R+M, R+O+P, HiFuse) and both models, on the tiny graph. This is the
//! contract that makes Fig. 8/9/11-style numbers backend-independent: a
//! dispatch count means the same thing whether modules are interpreted
//! (sim) or compiled (PJRT), because both record through the same
//! `Counters` at the same call sites.

use hifuse::coordinator::{prepare_cpu, prepare_graph_layout, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::step::Dims;
use hifuse::models::{plan, ModelKind};
use hifuse::runtime::{ExecBackend, Phase, SimBackend, Stage};
use hifuse::sampler::{NeighborSampler, SamplerCfg};
use hifuse::util::{Rng, WorkerPool};

#[test]
fn sim_counts_match_plan_for_every_ladder_mode_and_model() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let d = Dims::from_backend(&eng);
    let cfg = TrainCfg {
        epochs: 1,
        batch_size: 8,
        fanout: 3,
        lr: 0.05,
        seed: 42,
        threads: 2,
        producers: 0,
    };
    let scfg = SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: d.ns, ep: d.ep };

    let mut modes = OptConfig::ablation_ladder();
    modes.push(("HiFuse+S", OptConfig::parse("hifuse+stacked").unwrap()));
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        for &(name, opt) in &modes {
            let mut g = tiny_graph(5);
            prepare_graph_layout(&mut g, &opt);
            let mut tr = Trainer::new(&eng, &g, model, opt, cfg).unwrap();
            // Live-relation counts per layer from the sampler oracle drive
            // the analytic prediction.
            let mb = NeighborSampler::new(&g, scfg).sample(&Rng::new(42), 0, 0);
            let live: Vec<usize> = mb
                .oracle_edges
                .iter()
                .map(|rels| rels.iter().filter(|e| !e.is_empty()).count())
                .collect();
            let expect = plan::expected_counts(model, &opt, g.n_relations(), &live);

            eng.reset_counters(false);
            let pool = WorkerPool::new(cfg.threads);
            let prep = prepare_cpu(&g, scfg, &d, &opt, &pool, &Rng::new(42), 0, 0);
            tr.compute_batch(prep).unwrap();
            let c = eng.counters().borrow();
            for stage in [
                Stage::SemanticBuild,
                Stage::Projection,
                Stage::Aggregation,
                Stage::Fusion,
                Stage::Head,
            ] {
                for phase in [Phase::Fwd, Phase::Bwd] {
                    assert_eq!(
                        c.count_phase(stage, phase),
                        expect.get(stage, phase),
                        "{} {name}: stage {stage:?} {phase:?}",
                        model.name()
                    );
                }
            }
            assert_eq!(c.total(), expect.total(), "{} {name} total", model.name());
        }
    }
}

/// The paper's headline effect end-to-end on the sim backend: every rung
/// of the ladder dispatches no more kernels than base, and full HiFuse
/// strictly fewer. (The middle rungs are not mutually ordered — merging
/// and offloading cut different stages — so only base/HiFuse bracket.)
#[test]
fn hifuse_launches_strictly_fewer_kernels_than_every_rung() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let cfg = TrainCfg {
        epochs: 1,
        batch_size: 8,
        fanout: 3,
        lr: 0.05,
        seed: 42,
        threads: 2,
        producers: 0,
    };
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let mut totals = Vec::new();
        for (name, opt) in OptConfig::ablation_ladder() {
            let mut g = tiny_graph(1);
            prepare_graph_layout(&mut g, &opt);
            let mut tr = Trainer::new(&eng, &g, model, opt, cfg).unwrap();
            let m = tr.train_epoch(0).unwrap();
            totals.push((name, m.kernels_total));
        }
        let base = totals[0].1;
        let hifuse = totals.last().unwrap().1;
        for &(name, t) in &totals {
            assert!(t <= base, "{} {name}: {t} kernels exceeds base {base}", model.name());
        }
        assert!(
            hifuse < base,
            "{}: HiFuse did not reduce kernels: {hifuse} vs base {base}",
            model.name()
        );
        assert!(hifuse <= totals.iter().map(|&(_, t)| t).min().unwrap(), "{}", model.name());
    }
}
