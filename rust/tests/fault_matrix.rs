//! Fault-plane matrix (DESIGN.md §9): injected failures are *recoverable
//! scheduling events*, never semantic ones. Across the grid
//! {fault site × train/serve × replicas {1, 2} × pipeline on/off}:
//!
//! * the recovered trajectory (per-epoch loss/acc and every final
//!   parameter tensor) is bitwise identical to the fault-free run;
//! * retry / recovery / failover counters account for exactly the work
//!   the plan injected, and roll up per-lane → group;
//! * the zero-allocation steady state survives recovery (standby
//!   producers and retries recycle the same pools);
//! * admission control sheds deterministically — the shed set is a pure
//!   function of `(trace, batch_size, window, max_queue)`;
//! * the crash path works: a mid-epoch checkpoint cursor resumes to the
//!   bitwise-identical end state.

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, ReplicaMetrics,
    TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::checkpoint::{self, Cursor};
use hifuse::models::{ModelKind, Params};
use hifuse::runtime::{ExecBackend, SimBackend};
use hifuse::serving::{self, ServeOutcome, Trace};
use hifuse::util::{FaultPlan, FaultSite};

/// 6 batches/epoch on tiny's 24 train seeds; `producers: 2` pins the
/// stride layout the producer-fault accounting below relies on (producer
/// `p` owns schedule positions `p, p+2, p+4`).
fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 4, fanout: 3, lr: 0.05, seed: 42, threads: 4, producers: 2 }
}

fn plan(spec: &str) -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse(spec, 0).unwrap())
}

fn assert_params_eq(a: &Params, b: &Params, ctx: &str) {
    assert_eq!(a.w0, b.w0, "{ctx}: w0 diverged");
    assert_eq!(a.w1, b.w1, "{ctx}: w1 diverged");
    assert_eq!(a.a_src0, b.a_src0, "{ctx}: a_src0 diverged");
    assert_eq!(a.a_dst0, b.a_dst0, "{ctx}: a_dst0 diverged");
    assert_eq!(a.a_src1, b.a_src1, "{ctx}: a_src1 diverged");
    assert_eq!(a.a_dst1, b.a_dst1, "{ctx}: a_dst1 diverged");
}

/// One single-backend training run; returns the per-epoch (loss, acc)
/// trajectory, final params, and summed fault counters
/// (dispatch_retries, producer_recoveries).
fn run_trainer(
    pipeline: bool,
    spec: Option<&str>,
    epochs: u64,
) -> (Vec<(f64, f64)>, Params, u64, u64) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    if let Some(s) = spec {
        tr.set_fault_plan(plan(s));
    }
    let mut traj = Vec::new();
    let (mut retries, mut recov) = (0u64, 0u64);
    for e in 0..epochs {
        let m = tr.train_epoch(e).unwrap();
        traj.push((m.loss, m.acc));
        retries += m.dispatch_retries;
        recov += m.producer_recoveries;
    }
    (traj, tr.params.clone(), retries, recov)
}

fn engines(n: usize) -> Vec<SimBackend> {
    let t = replica_thread_budget(4, n);
    (0..n).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect()
}

/// One replica-group training run; returns the trajectory, final params,
/// and the full per-epoch metrics for counter-rollup assertions.
fn run_group(
    replicas: usize,
    pipeline: bool,
    spec: Option<&str>,
    epochs: u64,
) -> (Vec<(f64, f64)>, Params, Vec<ReplicaMetrics>) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
            .unwrap();
    if let Some(s) = spec {
        grp.set_fault_plan(plan(s));
    }
    let ms: Vec<ReplicaMetrics> = (0..epochs).map(|e| grp.train_epoch(e).unwrap()).collect();
    let traj = ms.iter().map(|m| (m.group.loss, m.group.acc)).collect();
    (traj, grp.params.clone(), ms)
}

/// Transient dispatch faults retry with a bounded budget and change
/// nothing: bitwise trajectory and parameter parity across the full
/// {replicas × pipeline} grid, with retries == the plan's explicit count.
#[test]
fn dispatch_faults_retry_and_preserve_the_trajectory() {
    let spec = "dispatch@0:2,dispatch@1:4x3";
    let planned = plan(spec).planned(FaultSite::Dispatch);
    assert_eq!(planned, 4);
    for pipeline in [false, true] {
        let (base_t, base_p, base_r, _) = run_trainer(pipeline, None, 2);
        assert_eq!(base_r, 0, "fault-free run must not count retries");
        let (t, p, retries, _) = run_trainer(pipeline, Some(spec), 2);
        assert_eq!(t, base_t, "pipeline={pipeline}: trajectory diverged");
        assert_params_eq(&p, &base_p, &format!("trainer pipeline={pipeline}"));
        assert_eq!(retries, planned, "pipeline={pipeline}: retry accounting");
    }
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            let (base_t, base_p, _) = run_group(replicas, pipeline, None, 2);
            let (t, p, ms) = run_group(replicas, pipeline, Some(spec), 2);
            let ctx = format!("replicas={replicas} pipeline={pipeline}");
            assert_eq!(t, base_t, "{ctx}: trajectory diverged");
            assert_params_eq(&p, &base_p, &ctx);
            let retries: u64 = ms.iter().map(|m| m.group.dispatch_retries).sum();
            assert_eq!(retries, planned, "{ctx}: retry accounting");
        }
    }
}

/// The resident path (`--mode resident`, DESIGN.md §7) recovers dispatch
/// faults the same way: every device-resident dispatch is pure (its
/// arguments are untouched device buffers), so the bounded retry replays
/// it bit-for-bit. Trajectory and final params stay bitwise equal to the
/// fault-free resident run — which `tests/residency.rs` pins to the
/// host-staged trajectory — with retries exactly as planned, on the
/// single-backend and replica paths.
#[test]
fn resident_dispatch_faults_retry_and_preserve_the_trajectory() {
    let spec = "dispatch@0:2,dispatch@1:4x3";
    let planned = plan(spec).planned(FaultSite::Dispatch);
    let opt_of = |pipeline| OptConfig {
        stacked_proj: true,
        dev_resident: true,
        pipeline,
        ..OptConfig::hifuse()
    };
    let run = |pipeline: bool, spec: Option<&str>| -> (Vec<(f64, f64)>, Params, u64) {
        let opt = opt_of(pipeline);
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        if let Some(s) = spec {
            tr.set_fault_plan(plan(s));
        }
        let mut traj = Vec::new();
        let mut retries = 0u64;
        for e in 0..2 {
            let m = tr.train_epoch(e).unwrap();
            traj.push((m.loss, m.acc));
            retries += m.dispatch_retries;
        }
        tr.sync_params().unwrap(); // device params are authoritative
        (traj, tr.params.clone(), retries)
    };
    for pipeline in [false, true] {
        let (base_t, base_p, base_r) = run(pipeline, None);
        assert_eq!(base_r, 0, "fault-free resident run must not count retries");
        let (t, p, retries) = run(pipeline, Some(spec));
        assert_eq!(t, base_t, "resident pipeline={pipeline}: trajectory diverged");
        assert_params_eq(&p, &base_p, &format!("resident trainer pipeline={pipeline}"));
        assert_eq!(retries, planned, "resident pipeline={pipeline}: retry accounting");
    }
    // Replica lanes: device grads pulled over the peer channel feed the
    // unchanged host all-reduce; a retried lane dispatch must not skew it.
    let run_grp = |replicas: usize, spec: Option<&str>| -> (Vec<(f64, f64)>, Params, u64) {
        let opt = opt_of(true);
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp =
            ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
                .unwrap();
        if let Some(s) = spec {
            grp.set_fault_plan(plan(s));
        }
        let ms: Vec<ReplicaMetrics> = (0..2).map(|e| grp.train_epoch(e).unwrap()).collect();
        let traj = ms.iter().map(|m| (m.group.loss, m.group.acc)).collect();
        let retries = ms.iter().map(|m| m.group.dispatch_retries).sum();
        (traj, grp.params.clone(), retries)
    };
    for replicas in [1usize, 2] {
        let (base_t, base_p, _) = run_grp(replicas, None);
        let (t, p, retries) = run_grp(replicas, Some(spec));
        let ctx = format!("resident replicas={replicas}");
        assert_eq!(t, base_t, "{ctx}: trajectory diverged");
        assert_params_eq(&p, &base_p, &ctx);
        assert_eq!(retries, planned, "{ctx}: retry accounting");
    }
}

/// A fault burst past the retry budget is an error, not a hang or a wrong
/// answer — on both the single-backend and replica paths.
#[test]
fn dispatch_faults_past_the_retry_budget_bail() {
    let spec = "dispatch@0:1x4"; // 4 > MAX_DISPATCH_RETRIES
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    tr.set_fault_plan(plan(spec));
    assert!(tr.train_epoch(0).is_err(), "trainer must surface a hard dispatch fault");

    let mut grp =
        ReplicaGroup::new(engines(2), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    grp.set_fault_plan(plan(spec));
    assert!(grp.train_epoch(0).is_err(), "group must surface a hard dispatch fault");
}

/// A producer death mid-epoch is recovered by re-deriving every lost
/// batch from `(epoch_perm, seq)` on a standby producer — bitwise parity,
/// with recoveries counting exactly the dead worker's remaining stride.
#[test]
fn producer_death_recovers_bitwise() {
    // Death while producing batch 5 — the last position of its stride —
    // loses exactly one batch.
    let (base_t, base_p, _, base_rec) = run_trainer(true, None, 2);
    assert_eq!(base_rec, 0);
    let (t, p, _, rec) = run_trainer(true, Some("producer@0:5"), 2);
    assert_eq!(t, base_t, "single lost batch: trajectory diverged");
    assert_params_eq(&p, &base_p, "trainer producer@0:5");
    assert_eq!(rec, 1, "one lost batch => one recovery");

    // Death at position 0: producer 0's whole stride {0, 2, 4} is lost.
    let (t, p, _, rec) = run_trainer(true, Some("producer@0:0"), 2);
    assert_eq!(t, base_t, "lost stride: trajectory diverged");
    assert_params_eq(&p, &base_p, "trainer producer@0:0");
    assert_eq!(rec, 3, "a death at position 0 loses the producer's full stride");

    // Same contract through the replica lanes' feeds.
    for replicas in [1usize, 2] {
        let (base_t, base_p, _) = run_group(replicas, true, None, 2);
        let (t, p, ms) = run_group(replicas, true, Some("producer@0:5"), 2);
        let ctx = format!("group replicas={replicas} producer@0:5");
        assert_eq!(t, base_t, "{ctx}: trajectory diverged");
        assert_params_eq(&p, &base_p, &ctx);
        let rec: u64 = ms.iter().map(|m| m.group.producer_recoveries).sum();
        assert_eq!(rec, 1, "{ctx}: recovery accounting");
    }
}

/// A lane dying mid-epoch hands its remaining round slots to the first
/// surviving lane; the fixed-order merge keeps the trajectory bitwise
/// equal to fault-free, whatever the death position.
#[test]
fn lane_death_fails_over_bitwise() {
    // Batch 4 (round 1, lane 0), batch 0 (first batch of the epoch), and
    // an epoch-1 death on lane 1's share (batch 2).
    for spec in ["lane@0:4", "lane@0:0", "lane@1:2"] {
        for pipeline in [false, true] {
            let (base_t, base_p, _) = run_group(2, pipeline, None, 2);
            let (t, p, ms) = run_group(2, pipeline, Some(spec), 2);
            let ctx = format!("{spec} pipeline={pipeline}");
            assert_eq!(t, base_t, "{ctx}: trajectory diverged");
            assert_params_eq(&p, &base_p, &ctx);
            let fo: u64 = ms.iter().map(|m| m.group.lane_failovers).sum();
            assert_eq!(fo, 1, "{ctx}: failover accounting");
        }
    }
}

/// Zero survivors is an error, not undefined behavior: a lane fault with
/// one replica, and a cascade killing both of two replicas, both bail.
#[test]
fn lane_death_with_no_survivor_bails() {
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(1), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    grp.set_fault_plan(plan("lane@0:2"));
    assert!(grp.train_epoch(0).is_err(), "sole lane dying must error");

    let mut grp =
        ReplicaGroup::new(engines(2), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    grp.set_fault_plan(plan("lane@0:0,lane@0:5"));
    assert!(grp.train_epoch(0).is_err(), "cascading deaths of both lanes must error");
}

/// Per-lane fault counters roll up to the group totals, and a run mixing
/// all three sites still lands bitwise on the fault-free trajectory.
#[test]
fn fault_counters_roll_up_per_lane_to_group() {
    let spec = "dispatch@0:2,producer@0:5,lane@1:4";
    let (base_t, base_p, _) = run_group(2, true, None, 2);
    let (t, p, ms) = run_group(2, true, Some(spec), 2);
    assert_eq!(t, base_t, "mixed-site run: trajectory diverged");
    assert_params_eq(&p, &base_p, "mixed-site run");
    for (e, m) in ms.iter().enumerate() {
        let per =
            |f: fn(&hifuse::coordinator::EpochMetrics) -> u64| -> u64 {
                m.per_replica.iter().map(f).sum()
            };
        assert_eq!(m.group.dispatch_retries, per(|r| r.dispatch_retries), "epoch {e}");
        assert_eq!(m.group.producer_recoveries, per(|r| r.producer_recoveries), "epoch {e}");
        assert_eq!(m.group.lane_failovers, per(|r| r.lane_failovers), "epoch {e}");
    }
    assert_eq!(ms.iter().map(|m| m.group.dispatch_retries).sum::<u64>(), 1);
    assert_eq!(ms.iter().map(|m| m.group.producer_recoveries).sum::<u64>(), 1);
    assert_eq!(ms.iter().map(|m| m.group.lane_failovers).sum::<u64>(), 1);
}

/// Recovery preserves the zero-allocation steady state: with faults
/// firing in *every* epoch, post-warm-up epochs still never miss the
/// backend arena (standby producers and retries recycle pooled buffers).
#[test]
fn recovery_keeps_the_zero_alloc_steady_state() {
    let spec = "producer@0:5,producer@1:5,producer@2:5,dispatch@1:1,dispatch@2:3";
    let (base_t, base_p, _, _) = run_trainer(true, None, 3);
    let opt = OptConfig::hifuse();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    tr.set_fault_plan(plan(spec));
    let ms: Vec<_> = (0..3).map(|e| tr.train_epoch(e).unwrap()).collect();
    let traj: Vec<(f64, f64)> = ms.iter().map(|m| (m.loss, m.acc)).collect();
    assert_eq!(traj, base_t, "faulted steady-state run: trajectory diverged");
    assert_params_eq(&tr.params, &base_p, "faulted steady-state run");
    assert_eq!(ms[0].producer_recoveries, 1, "epoch 0 recovery");
    assert_eq!(ms[2].dispatch_retries, 1, "epoch 2 retry");
    // EpochMetrics.arena is the cumulative snapshot at epoch end: flat
    // misses between epochs 1 and 2 = zero allocations in epoch 2, even
    // though epoch 2 both recovered a batch and retried a dispatch.
    assert_eq!(
        ms[2].arena.misses, ms[1].arena.misses,
        "steady-state epoch with faults allocated ({:?} -> {:?})",
        ms[1].arena, ms[2].arena
    );
    assert!(ms[2].arena.hits > ms[1].arena.hits, "arena unused");
}

/// Crash consistency: training interrupted mid-epoch, checkpointed with a
/// cursor, reloaded, and resumed from `(epoch, batch)` lands bitwise on
/// the uninterrupted end state — through the atomic-save file format.
#[test]
fn mid_epoch_resume_matches_the_uninterrupted_run() {
    for pipeline in [false, true] {
        let (_, base_p, _, _) = run_trainer(pipeline, None, 2);

        let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let path = std::env::temp_dir().join(format!("hifuse_fault_resume_{pipeline}.ckpt"));

        // "Crash" after batch 3 of epoch 0: persist params + cursor.
        {
            let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
            let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
            tr.train_epoch_range(0, 0, 3).unwrap();
            checkpoint::save_at(&tr.params, Cursor { epoch: 0, batch: 3 }, &path).unwrap();
        }

        // Fresh process: reload, finish epoch 0 from the cursor, run epoch 1.
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        let (params, cur) = checkpoint::load_with_cursor(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(cur, Cursor { epoch: 0, batch: 3 });
        tr.params = params;
        tr.train_epoch_range(cur.epoch, cur.batch as usize, usize::MAX).unwrap();
        tr.train_epoch(1).unwrap();
        assert_params_eq(&tr.params, &base_p, &format!("resume pipeline={pipeline}"));
    }
}

// ---------------------------------------------------------------- serve --

const WINDOW: u64 = 2_000;

/// Back-to-back arrivals (1M req/s of virtual time) so a bounded queue
/// actually overflows: batches close faster than the virtual server's
/// service rate.
fn burst_trace() -> Trace {
    serving::trace::generate(&tiny_graph(1), 42, 1_000_000.0, 24, 3)
}

fn serve_once(
    trace: &Trace,
    replicas: usize,
    pipeline: bool,
    max_queue: Option<usize>,
    spec: Option<&str>,
) -> (ServeOutcome, u64) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
            .unwrap();
    if let Some(s) = spec {
        grp.set_fault_plan(plan(s));
    }
    let out =
        serving::serve_bounded(&mut grp, trace, cfg().batch_size, WINDOW, max_queue).unwrap();
    let retries: u64 =
        grp.engines().iter().map(|e| e.counters().borrow().dispatch_retries).sum();
    (out, retries)
}

/// Admission control sheds whole batches deterministically: the shed set
/// is identical across the {replicas × pipeline} grid, every request is
/// either served or shed exactly once, and admitted predictions stay
/// bitwise equal to the unbounded run's.
#[test]
fn shedding_is_deterministic_and_fully_accounted() {
    let trace = burst_trace();
    let n = trace.requests.len();
    let (unbounded, _) = serve_once(&trace, 1, false, None, None);
    assert!(unbounded.shed.is_empty(), "no bound => no sheds");
    // The admission model now accounts backlog for unbounded runs too: a
    // burst trace piles the virtual queue well past one batch.
    assert!(unbounded.max_backlog >= 1, "burst trace must queue");
    assert!(unbounded.mean_queue_depth > 0.0, "burst trace has a busy span");
    let (reference, _) = serve_once(&trace, 1, false, Some(1), None);
    assert!(!reference.shed.is_empty(), "burst at queue depth 1 must shed");
    assert!(reference.hist.count() > 0, "something must still be served");
    assert_eq!(reference.hist.shed(), reference.shed.len() as u64);
    assert_eq!(reference.hist.count() + reference.hist.shed(), n as u64);
    assert!(reference.max_backlog <= 1, "backlog exceeded the bound");
    let shed_set: Vec<bool> =
        (0..n).map(|i| reference.shed.binary_search(&(i as u32)).is_ok()).collect();
    for (i, &s) in shed_set.iter().enumerate() {
        if s {
            assert!(reference.predictions[i].is_shed(), "shed request {i} has rows");
            assert_eq!(reference.latencies[i], 0, "shed request {i} has latency");
        } else {
            assert!(!reference.predictions[i].is_shed(), "admitted request {i} marked shed");
            assert_eq!(
                reference.predictions[i], unbounded.predictions[i],
                "admitted request {i}: prediction diverged from the unbounded run"
            );
        }
    }
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            let (out, _) = serve_once(&trace, replicas, pipeline, Some(1), None);
            assert_eq!(
                out.shed, reference.shed,
                "replicas={replicas} pipeline={pipeline}: shed set diverged"
            );
            assert_eq!(
                out.predictions, reference.predictions,
                "replicas={replicas} pipeline={pipeline}: predictions diverged"
            );
        }
    }
}

/// Dispatch faults on the serve path retry transparently: predictions
/// stay bitwise identical and the retries land in the engine counters.
#[test]
fn serve_dispatch_faults_retry_without_changing_predictions() {
    let trace = burst_trace();
    let (base, base_retries) = serve_once(&trace, 2, true, None, None);
    assert_eq!(base_retries, 0);
    let (out, retries) = serve_once(&trace, 2, true, None, Some("dispatch@0:0x2,dispatch@0:1"));
    assert_eq!(out.predictions, base.predictions, "faulted serve: predictions diverged");
    assert_eq!(retries, 3, "serve retry accounting");
}
