//! Device-residency suite (DESIGN.md §7): in `--mode resident` the
//! activation chain `feature_gather → projection → aggregation → head`
//! hands `DevBuf`s between dispatches and the optimizer runs on-device, so
//! the only steady-state PCIe traffic is
//!
//!   H2D: the batch metadata — scatter indices (or the raw slab with the
//!        cache off), merged edge tensors, labels, seed mask — plus the
//!        packed miss rows when `--cache-frac < 1`;
//!   D2H: the head scalars (loss + ncorrect, 8 bytes/batch) in training,
//!        the `[NS, C]` logits slab in serving.
//!
//! Every byte is pinned **exactly**, per batch, from the profile dims — no
//! inequalities. Alongside the byte ledger the suite pins the trajectory:
//! device-resident runs are bitwise identical to the host-staged
//! `hifuse+stacked` plan across cache-frac {0, 0.25, 1.0} × replicas
//! {1, 2} × pipeline on/off, in training and serving, and the
//! `feature_gather` device path matches a host oracle bit-for-bit on its
//! edge patterns (pad rows, miss rows, duplicate slots, empty types).

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, TrainCfg, Trainer,
    DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::step::Dims;
use hifuse::models::{ModelKind, Params};
use hifuse::runtime::{Arg, ExecBackend, Phase, ResidentStore, SimBackend, Stage};
use hifuse::serving;
use hifuse::util::HostTensor;

/// 6 batches/epoch on tiny's 24 train seeds.
fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 4, fanout: 3, lr: 0.05, seed: 42, threads: 4, producers: 2 }
}

fn store_for(g: &hifuse::graph::HeteroGraph, frac: f64) -> Arc<ResidentStore> {
    Arc::new(ResidentStore::build(g, frac, 160, 42))
}

fn engines(n: usize) -> Vec<SimBackend> {
    let t = replica_thread_budget(4, n);
    (0..n).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect()
}

/// The host-staged plan the resident mode must match bitwise: the same
/// fully-merged dispatch set, activations staged through host memory.
fn host_opt(pipeline: bool) -> OptConfig {
    OptConfig { stacked_proj: true, pipeline, ..OptConfig::hifuse() }
}

fn resident_opt(pipeline: bool) -> OptConfig {
    OptConfig { pipeline, ..OptConfig::resident() }
}

fn assert_params_eq(a: &Params, b: &Params, ctx: &str) {
    assert_eq!(a.w0, b.w0, "{ctx}: w0 diverged");
    assert_eq!(a.w1, b.w1, "{ctx}: w1 diverged");
    assert_eq!(a.a_src0, b.a_src0, "{ctx}: a_src0 diverged");
    assert_eq!(a.a_dst0, b.a_dst0, "{ctx}: a_dst0 diverged");
    assert_eq!(a.a_src1, b.a_src1, "{ctx}: a_src1 diverged");
    assert_eq!(a.a_dst1, b.a_dst1, "{ctx}: a_dst1 diverged");
}

/// Exact per-batch H2D bytes of the resident step, derived from the
/// profile dims (all f32/i32 = 4 bytes):
///   cached:    gather idx [TPAD, NS]  + edges + labels + seed mask
///   cache-off: full slab [TPAD, NS, F] + edges + labels + seed mask
/// where edges = 2 layers × {src, dst, valid} × [RPAD * EP].
fn h2d_per_batch(d: &Dims, cached: bool) -> u64 {
    let edges = 2 * 3 * (d.rpad * d.ep) as u64 * 4;
    let meta = 2 * d.ns as u64 * 4; // labels [NS] i32 + seed_mask [NS] f32
    let feat = if cached {
        (d.tpad * d.ns) as u64 * 4 // scatter indices only
    } else {
        (d.tpad * d.ns * d.f) as u64 * 4 // the whole collected slab
    };
    feat + edges + meta
}

/// D2H per training batch: the loss and ncorrect scalars, nothing else.
const TRAIN_D2H_PER_BATCH: u64 = 8;

// ------------------------------------------------------------- transfers --

/// Per-batch ledger on the single-backend trainer: every batch of the
/// epoch (not just in aggregate) moves exactly the pinned byte counts, for
/// cache-frac {off, 0.25, 1.0}. `train_epoch_range` resets the counters
/// per call, so each call is one batch's isolated ledger.
#[test]
fn resident_train_moves_exactly_the_batch_metadata() {
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        for frac in [None, Some(0.25), Some(1.0)] {
            let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
            let d = Dims::from_backend(&eng);
            let opt = resident_opt(false);
            let mut g = tiny_graph(1);
            prepare_graph_layout(&mut g, &opt);
            let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
            if let Some(f) = frac {
                tr.attach_cache(store_for(&g, f)).unwrap();
            }
            let base = h2d_per_batch(&d, frac.is_some());
            for b in 0..6 {
                let m = tr.train_epoch_range(0, b, b + 1).unwrap();
                let ctx = format!("{} frac {frac:?} batch {b}", model.name());
                // Miss rows are the only data-dependent term: F floats per
                // missed slot, zero at frac 1.0.
                let miss = m.cache_misses * d.f as u64 * 4;
                if frac == Some(1.0) {
                    assert_eq!(m.cache_misses, 0, "{ctx}: full cache missed");
                }
                assert_eq!(m.h2d_bytes, base + miss, "{ctx}: h2d");
                assert_eq!(m.d2h_bytes, TRAIN_D2H_PER_BATCH, "{ctx}: d2h");
            }
        }
    }
}

/// The same ledger holds through the pipelined consumer and across whole
/// epochs: per-epoch totals are exactly `batches ×` the per-batch pins.
#[test]
fn resident_epoch_totals_scale_per_batch_pins() {
    for pipeline in [false, true] {
        for frac in [None, Some(1.0)] {
            let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
            let d = Dims::from_backend(&eng);
            let opt = resident_opt(pipeline);
            let mut g = tiny_graph(1);
            prepare_graph_layout(&mut g, &opt);
            let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
            if let Some(f) = frac {
                tr.attach_cache(store_for(&g, f)).unwrap();
            }
            for epoch in 0..3 {
                let m = tr.train_epoch(epoch).unwrap();
                let n = m.batches as u64;
                let ctx = format!("pipeline={pipeline} frac {frac:?} epoch {epoch}");
                assert_eq!(m.h2d_bytes, n * h2d_per_batch(&d, frac.is_some()), "{ctx}: h2d");
                assert_eq!(m.d2h_bytes, n * TRAIN_D2H_PER_BATCH, "{ctx}: d2h");
            }
        }
    }
}

/// Replica lanes keep the same per-batch PCIe ledger; the round parameter
/// broadcast and the per-batch gradient pulls ride the peer interconnect
/// (`p2p_bytes`), which stays zero in the host-staged modes.
#[test]
fn resident_replica_traffic_is_pinned_and_peer_routed() {
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            let opt = resident_opt(pipeline);
            let mut g = tiny_graph(1);
            prepare_graph_layout(&mut g, &opt);
            let mut grp =
                ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
                    .unwrap();
            grp.attach_cache(store_for(&g, 1.0)).unwrap();
            let d = Dims::from_backend(&grp.engines()[0]);
            for epoch in 0..2 {
                let m = grp.train_epoch(epoch).unwrap();
                let n = m.group.batches as u64;
                let ctx = format!("replicas={replicas} pipeline={pipeline} epoch {epoch}");
                assert_eq!(m.group.h2d_bytes, n * h2d_per_batch(&d, true), "{ctx}: h2d");
                assert_eq!(m.group.d2h_bytes, n * TRAIN_D2H_PER_BATCH, "{ctx}: d2h");
                assert!(m.group.p2p_bytes > 0, "{ctx}: no peer traffic recorded");
                let lane_p2p: u64 = m.per_replica.iter().map(|r| r.p2p_bytes).sum();
                assert_eq!(m.group.p2p_bytes, lane_p2p, "{ctx}: p2p rollup");
            }
        }
    }
    // Host-staged replicas never touch the peer channel.
    let opt = host_opt(false);
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp =
        ReplicaGroup::new(engines(2), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    let m = grp.train_epoch(0).unwrap();
    assert_eq!(m.group.p2p_bytes, 0, "host-staged path recorded p2p traffic");
}

/// Serving ledger: per served batch, H2D is the same batch metadata and
/// D2H is exactly the `[NS, C]` logits slab — across replicas × pipeline
/// × cache on/off.
#[test]
fn resident_serve_moves_logits_only_d2h() {
    let trace = serving::trace::generate(&tiny_graph(1), 42, 10_000.0, 24, 3);
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for cached in [false, true] {
                let opt = resident_opt(pipeline);
                let mut g = tiny_graph(1);
                prepare_graph_layout(&mut g, &opt);
                let mut grp = ReplicaGroup::new(
                    engines(replicas),
                    &g,
                    ModelKind::Rgcn,
                    opt,
                    cfg(),
                    DEFAULT_ROUND,
                )
                .unwrap();
                if cached {
                    grp.attach_cache(store_for(&g, 1.0)).unwrap();
                }
                let d = Dims::from_backend(&grp.engines()[0]);
                // Clear the warm-up transfers (schema constants, the
                // resident slab) so the window is pure steady state.
                for e in grp.engines() {
                    e.reset_counters(false);
                }
                let out =
                    serving::serve_bounded(&mut grp, &trace, cfg().batch_size, 2_000, None)
                        .unwrap();
                let n = out.batches.len() as u64;
                assert!(n > 0, "trace produced no batches");
                let (mut h2d, mut d2h) = (0u64, 0u64);
                for e in grp.engines() {
                    let c = e.counters().borrow();
                    h2d += c.h2d_bytes;
                    d2h += c.d2h_bytes;
                }
                let ctx = format!("replicas={replicas} pipeline={pipeline} cached={cached}");
                assert_eq!(h2d, n * h2d_per_batch(&d, cached), "{ctx}: h2d");
                assert_eq!(d2h, n * (d.ns * d.c) as u64 * 4, "{ctx}: d2h");
            }
        }
    }
}

// -------------------------------------------------------------- parity ---

/// The tentpole contract: device-resident trajectories are bitwise the
/// host-staged `hifuse+stacked` trajectories — per-epoch loss/acc and
/// every final parameter tensor — across both models × pipeline on/off ×
/// cache-frac {0, 0.25, 1.0}.
#[test]
fn resident_matches_host_staged_bitwise() {
    let run = |model: ModelKind, opt: OptConfig, frac: f64| -> (Vec<(f64, f64)>, Params) {
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
        if frac > 0.0 {
            tr.attach_cache(store_for(&g, frac)).unwrap();
        }
        let traj = (0..3)
            .map(|e| {
                let m = tr.train_epoch(e).unwrap();
                (m.loss, m.acc)
            })
            .collect();
        // Read the authoritative device params back (no-op host-staged).
        tr.sync_params().unwrap();
        (traj, tr.params.clone())
    };
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let (ref_traj, ref_params) = run(model, host_opt(false), 0.0);
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25, 1.0] {
                let (t, p) = run(model, resident_opt(pipeline), frac);
                let ctx = format!("{} resident pipeline={pipeline} frac={frac}", model.name());
                assert_eq!(t, ref_traj, "{ctx}: trajectory diverged");
                assert_params_eq(&p, &ref_params, &ctx);
            }
        }
    }
}

/// Replica groups: the resident lanes (device grads pulled over the peer
/// channel into the unchanged host all-reduce) land bitwise on the
/// host-staged group trajectory for every replicas × pipeline × frac.
#[test]
fn resident_replicas_match_host_staged_bitwise() {
    let run = |opt: OptConfig, replicas: usize, frac: f64| -> (Vec<(f64, f64)>, Params) {
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp =
            ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgat, opt, cfg(), DEFAULT_ROUND)
                .unwrap();
        if frac > 0.0 {
            grp.attach_cache(store_for(&g, frac)).unwrap();
        }
        let traj = (0..2)
            .map(|e| {
                let m = grp.train_epoch(e).unwrap();
                (m.group.loss, m.group.acc)
            })
            .collect();
        (traj, grp.params.clone())
    };
    let (ref_traj, ref_params) = run(host_opt(false), 1, 0.0);
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 1.0] {
                let (t, p) = run(resident_opt(pipeline), replicas, frac);
                let ctx = format!("replicas={replicas} pipeline={pipeline} frac={frac}");
                assert_eq!(t, ref_traj, "{ctx}: trajectory diverged");
                assert_params_eq(&p, &ref_params, &ctx);
            }
        }
    }
}

/// Serving: resident predictions (extracted on-device by `slab_pick`,
/// fetched as the lone D2H) are bitwise the host-staged predictions for
/// every request, across the full grid.
#[test]
fn resident_serve_predictions_match_host_staged() {
    let trace = serving::trace::generate(&tiny_graph(1), 42, 10_000.0, 24, 3);
    let serve = |opt: OptConfig, replicas: usize, cached: bool| {
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp =
            ReplicaGroup::new(engines(replicas), &g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND)
                .unwrap();
        if cached {
            grp.attach_cache(store_for(&g, 1.0)).unwrap();
        }
        serving::serve_bounded(&mut grp, &trace, cfg().batch_size, 2_000, None)
            .unwrap()
            .predictions
    };
    let reference = serve(host_opt(false), 1, false);
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for cached in [false, true] {
                let p = serve(resident_opt(pipeline), replicas, cached);
                assert_eq!(
                    p, reference,
                    "replicas={replicas} pipeline={pipeline} cached={cached}: \
                     predictions diverged"
                );
            }
        }
    }
}

// ------------------------------------------------------------ dispatches --

/// The resident plan's dispatch budget, measured: 14 kernels per RGCN
/// batch, 18 per RGAT batch (the fully-merged host plan + exactly one
/// fused on-device SGD at (Head, Bwd)), plus one `feature_gather` at
/// (Collection, Fwd) per batch when the cache is attached — matching
/// `plan::expected_counts`.
#[test]
fn resident_dispatch_counts_are_pinned() {
    for (model, per_batch) in [(ModelKind::Rgcn, 14usize), (ModelKind::Rgat, 18)] {
        for cached in [false, true] {
            let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
            let opt = resident_opt(false);
            let mut g = tiny_graph(1);
            prepare_graph_layout(&mut g, &opt);
            let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
            if cached {
                tr.attach_cache(store_for(&g, 1.0)).unwrap();
            }
            let m = tr.train_epoch(0).unwrap();
            let expect = (per_batch + usize::from(cached)) * m.batches;
            let ctx = format!("{} cached={cached}", model.name());
            assert_eq!(m.kernels_total, expect, "{ctx}: dispatch count");
            let c = eng.counters().borrow();
            assert_eq!(
                c.count_phase(Stage::Head, Phase::Bwd),
                m.batches,
                "{ctx}: one fused SGD per batch"
            );
            assert_eq!(
                c.count_phase(Stage::Collection, Phase::Fwd),
                if cached { m.batches } else { 0 },
                "{ctx}: gather dispatches"
            );
        }
    }
}

/// The resident path keeps the zero-allocation steady state: arena misses
/// and producer-pool construction are flat across post-warm-up epochs.
#[test]
fn resident_keeps_the_zero_alloc_steady_state() {
    for pipeline in [false, true] {
        let eng = SimBackend::builtin_threaded("tiny", 2).unwrap();
        let opt = resident_opt(pipeline);
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgat, opt, cfg()).unwrap();
        tr.attach_cache(store_for(&g, 0.25)).unwrap();
        tr.train_epoch(0).unwrap();
        let warm = tr.train_epoch(1).unwrap();
        let steady = tr.train_epoch(2).unwrap();
        assert_eq!(
            steady.arena.misses, warm.arena.misses,
            "pipeline {pipeline}: steady-state dispatch allocated ({:?} -> {:?})",
            warm.arena, steady.arena
        );
        assert_eq!(
            steady.producer.fresh, warm.producer.fresh,
            "pipeline {pipeline}: steady state constructed a buffer set"
        );
        assert_eq!(
            steady.producer.grown, warm.producer.grown,
            "pipeline {pipeline}: steady state grew a pooled buffer"
        );
        assert!(steady.producer.reused > warm.producer.reused);
    }
}

// ------------------------------------------------------ gather property --

/// Host oracle for the `feature_gather` semantics: slot index `>= 0` reads
/// the cache row, `-1` emits a zero pad row, `<= -2` reads miss row
/// `-idx - 2`. Mirrors the CPU collector's `collect_into` assembly.
fn gather_oracle(cache: &[f32], miss: &[f32], idx: &[i32], f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; idx.len() * f];
    for (slot, &i) in idx.iter().enumerate() {
        let row = &mut out[slot * f..(slot + 1) * f];
        if i >= 0 {
            row.copy_from_slice(&cache[i as usize * f..(i as usize + 1) * f]);
        } else if i <= -2 {
            let m = (-i - 2) as usize;
            row.copy_from_slice(&miss[m * f..(m + 1) * f]);
        } // i == -1: stays the zero pad row
    }
    out
}

/// Dispatch `feature_gather` on the sim backend against the oracle,
/// comparing bit patterns (not float equality) row for row.
fn check_gather(eng: &SimBackend, d: &Dims, cache: &[f32], miss: &[f32], idx: &[i32], ctx: &str) {
    let cslots = eng.cst("CSLOTS");
    let cache_t = HostTensor::f32(cache.to_vec(), &[cslots, d.f]);
    let miss_t = HostTensor::f32(miss.to_vec(), &[d.tpad * d.ns, d.f]);
    let idx_t = HostTensor::i32(idx.to_vec(), &[d.tpad, d.ns]);
    let cache_dev = eng.upload(&cache_t, cache.len()).unwrap();
    let miss_dev = eng.upload(&miss_t, miss.len()).unwrap();
    let out = eng
        .run_dev(
            "feature_gather",
            Stage::Collection,
            Phase::Fwd,
            &[Arg::Dev(&cache_dev), Arg::Dev(&miss_dev), Arg::Host(&idx_t)],
        )
        .unwrap();
    let got = eng.fetch(out).unwrap();
    let got = got.as_f32().unwrap();
    let want = gather_oracle(cache, miss, idx, d.f);
    assert_eq!(got.len(), want.len(), "{ctx}: shape");
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{ctx}: element {i} (slot {}, col {}) differs: {a} vs {b}",
            i / d.f,
            i % d.f
        );
    }
}

/// Property sweep over the gather index patterns the producer can emit:
/// all-pad, hit-only with duplicate slots, whole-batch miss, empty types
/// (a type whose rows are all pads), and a deterministic mixed pattern.
#[test]
fn feature_gather_matches_the_host_oracle_bitwise() {
    let eng = SimBackend::builtin("tiny").unwrap();
    let d = Dims::from_backend(&eng);
    let cslots = eng.cst("CSLOTS");
    let slots = d.tpad * d.ns;
    // Distinct, sign-mixed row contents so any slot/row confusion flips
    // bits: cache row r column c = -(r + c/16), miss row m column c
    // = 1000 + m + c/16.
    let cache: Vec<f32> =
        (0..cslots * d.f).map(|i| -((i / d.f) as f32 + (i % d.f) as f32 / 16.0)).collect();
    let miss: Vec<f32> =
        (0..slots * d.f).map(|i| 1000.0 + (i / d.f) as f32 + (i % d.f) as f32 / 16.0).collect();

    // All pad: the output must be entirely zero rows.
    check_gather(&eng, &d, &cache, &miss, &vec![-1i32; slots], "all-pad");

    // Hits with duplicates: every slot reads cache row (slot % 5) — rows
    // reused across many slots, like a hot vertex sampled repeatedly.
    let dup: Vec<i32> = (0..slots).map(|s| (s % 5) as i32).collect();
    check_gather(&eng, &d, &cache, &miss, &dup, "duplicate-hits");

    // Whole-batch miss: every slot reads its own packed miss row.
    let all_miss: Vec<i32> = (0..slots).map(|s| -2 - s as i32).collect();
    check_gather(&eng, &d, &cache, &miss, &all_miss, "whole-batch-miss");

    // Empty types: type 0's rows all pad, later types mix hit/miss/pad.
    let mut mixed = vec![-1i32; slots];
    for (s, v) in mixed.iter_mut().enumerate().skip(d.ns) {
        *v = match s % 3 {
            0 => ((s * 7) % cslots) as i32,     // scattered cache hits
            1 => -2 - ((s * 3) % slots) as i32, // shared miss rows
            _ => -1,                            // interior padding
        };
    }
    check_gather(&eng, &d, &cache, &miss, &mixed, "empty-type-mixed");

    // Boundary rows: the last cache slot and the last miss row.
    let mut edge = vec![-1i32; slots];
    edge[0] = (cslots - 1) as i32;
    edge[1] = -2 - (slots - 1) as i32;
    check_gather(&eng, &d, &cache, &miss, &edge, "boundary-rows");
}
