//! Integration tests over the built-in `tiny` profile on the default
//! SimBackend: full training loops through the dispatch runtime,
//! equivalence of execution plans, and measured kernel counts vs the
//! analytic plan. Runs on a clean checkout — no AOT artifacts, no Python.

use hifuse::coordinator::{
    gpu_select, prepare_graph_layout, AssembleScratch, OptConfig, TrainCfg, Trainer,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::runtime::SimBackend;
use hifuse::sampler::{NeighborSampler, SamplerCfg};
use hifuse::semantic;
use hifuse::util::Rng;

fn backend() -> SimBackend {
    SimBackend::builtin("tiny").unwrap()
}

fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 8, fanout: 3, lr: 0.05, seed: 42, threads: 2, producers: 0 }
}

fn epoch_losses(model: ModelKind, opt: OptConfig, epochs: usize) -> Vec<f64> {
    let eng = backend();
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, model, opt, cfg()).unwrap();
    (0..epochs).map(|e| tr.train_epoch(e as u64).unwrap().loss).collect()
}

#[test]
fn rgcn_baseline_loss_decreases() {
    let losses = epoch_losses(ModelKind::Rgcn, OptConfig::baseline(), 5);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn rgcn_hifuse_loss_decreases() {
    let losses = epoch_losses(ModelKind::Rgcn, OptConfig::hifuse(), 5);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn rgat_hifuse_loss_decreases() {
    let losses = epoch_losses(ModelKind::Rgat, OptConfig::hifuse(), 5);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

/// THE equivalence gate: every execution plan computes the same training
/// trajectory (same batches, same math) up to float reassociation.
#[test]
fn all_plans_agree_on_losses() {
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let base = epoch_losses(model, OptConfig::baseline(), 2);
        for (name, opt) in OptConfig::ablation_ladder().into_iter().skip(1) {
            let l = epoch_losses(model, opt, 2);
            for (a, b) in base.iter().zip(&l) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "{} {name}: losses diverge: base {a} vs {b}",
                    model.name()
                );
            }
        }
        // Extension config too.
        let l = epoch_losses(model, OptConfig::parse("hifuse+stacked").unwrap(), 2);
        for (a, b) in base.iter().zip(&l) {
            assert!((a - b).abs() < 1e-3, "{} stacked diverges: {a} vs {b}", model.name());
        }
    }
}

/// Backend-module edge selection must equal the CPU implementations.
#[test]
fn gpu_select_matches_cpu_select() {
    let eng = backend();
    let d = Dims::from_backend(&eng);
    let g = tiny_graph(7);
    let scfg = SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: d.ns, ep: d.ep };
    let mb = NeighborSampler::new(&g, scfg).sample(&Rng::new(3), 0, 0);
    let mut scratch = AssembleScratch::default();
    for tagged in &mb.tagged {
        let gpu = gpu_select(&eng, &d, tagged, g.n_relations(), &mut scratch).unwrap();
        let cpu = semantic::select_serial(tagged, g.n_relations());
        let par = semantic::select_parallel(tagged, g.n_relations(), 3);
        for r in 0..g.n_relations() {
            assert_eq!(gpu[r].src, cpu[r].src, "rel {r} src");
            assert_eq!(gpu[r].dst, cpu[r].dst, "rel {r} dst");
            assert_eq!(par[r].src, cpu[r].src, "rel {r} parallel src");
        }
    }
}

// NOTE: measured-counts-vs-analytic-plan parity lives in
// tests/backend_parity.rs, which covers the full ablation ladder plus the
// stacked extension for both models — one canonical copy of that contract.

/// Pipelined execution computes the same losses as sequential.
#[test]
fn pipeline_matches_sequential() {
    let mut seq_opt = OptConfig::hifuse();
    seq_opt.pipeline = false;
    let seq = epoch_losses(ModelKind::Rgcn, seq_opt, 3);
    let pipe = epoch_losses(ModelKind::Rgcn, OptConfig::hifuse(), 3);
    for (a, b) in seq.iter().zip(&pipe) {
        assert!((a - b).abs() < 1e-6, "pipeline diverges: {a} vs {b}");
    }
}

/// HiFuse must reduce kernel count vs baseline (Fig. 8 direction) on the
/// tiny profile already.
#[test]
fn hifuse_reduces_kernels() {
    let eng = backend();
    let mut totals = Vec::new();
    for opt in [OptConfig::baseline(), OptConfig::hifuse()] {
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
        let m = tr.train_epoch(0).unwrap();
        totals.push(m.kernels_total);
    }
    assert!(totals[1] < totals[0], "HiFuse did not reduce kernels: {totals:?}");
    let reduction = 1.0 - totals[1] as f64 / totals[0] as f64;
    assert!(reduction > 0.3, "reduction only {reduction:.2}");
}

/// Accuracy rises above chance after a few epochs (features are learnable
/// class-centroid Gaussians).
#[test]
fn training_beats_chance_accuracy() {
    let eng = backend();
    let mut g = tiny_graph(1);
    let opt = OptConfig::hifuse();
    prepare_graph_layout(&mut g, &opt);
    let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg()).unwrap();
    let mut last = 0.0;
    for e in 0..8 {
        last = tr.train_epoch(e).unwrap().acc;
    }
    let chance = 1.0 / g.num_classes as f64;
    assert!(last > chance + 0.1, "acc {last} not above chance {chance}");
}

/// CLI validation bails early with friendly messages instead of failing
/// deep inside a run: out-of-range fractions, zero worker counts,
/// conflicting trace flags, malformed fault specs, and a zero queue
/// bound are all rejected at parse time (ISSUE 7 satellite).
#[test]
fn cli_rejects_invalid_flag_combinations() {
    use hifuse::config::RunConfig;
    let argv = |s: &str| -> Vec<String> { s.split_whitespace().map(String::from).collect() };
    let err = |s: &str| RunConfig::from_args(&argv(s)).unwrap_err().to_string();

    assert!(err("--cache-frac 1.5").contains("[0, 1]"));
    assert!(err("--cache-frac -0.1").contains("[0, 1]"));
    assert!(err("--replicas 0").contains(">= 1"));
    assert!(err("--producers 0").contains(">= 1"));
    assert!(err("--rate 0").contains("positive"));
    assert!(err("--record-trace /tmp/a.bin --replay-trace /tmp/b.bin").contains("conflict"));
    assert!(err("--fault-spec gpu@0:0").contains("--fault-spec"));
    assert!(err("--max-queue 0").contains(">= 1"));

    // The same flags parse individually: validation is about the values,
    // not the features.
    let ok = RunConfig::from_args(&argv(
        "--cache-frac 0.5 --replicas 2 --producers 2 --rate 100 \
         --fault-spec dispatch@0:1 --fault-seed 9 --max-queue 4",
    ))
    .unwrap();
    assert_eq!(ok.max_queue, Some(4));
    assert!(ok.fault_plan().unwrap().is_some());
}
