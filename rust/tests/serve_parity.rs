//! Serving determinism regression tests (DESIGN.md §8): replaying a
//! recorded arrival trace is a *semantic* no-op under every scheduling
//! knob.
//!
//! * Per-request predictions are bitwise identical for
//!   `replicas ∈ {1, 2}` × pipeline on/off × `cache-frac ∈ {0, 0.25}` —
//!   the serve grid of the issue.
//! * Coalescing decisions (batch count, per-batch request membership,
//!   open/close ticks) are identical across the same grid: they are a
//!   pure function of the trace, never of the lane layout.
//! * The forward path keeps the zero-allocation steady state: arena
//!   misses and producer-pool stats are flat across post-warm-up serve
//!   passes, same contract as `tests/cache_parity.rs` for training.
//! * The latency histogram is well-formed: p50 ≤ p95 ≤ p99 and the
//!   sample count equals the request count.

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, OptConfig, ReplicaGroup, TrainCfg,
    DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};
use hifuse::serving::{self, ServeOutcome, Trace};

const WINDOW: u64 = 2_000;

fn cfg() -> TrainCfg {
    TrainCfg {
        epochs: 1,
        batch_size: 4,
        fanout: 3,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers: 2,
    }
}

fn test_trace() -> Trace {
    // Seed sets of 1..=3 on batch capacity 4: the coalescer exercises
    // multi-request batches, overflow closes, and window closes.
    serving::trace::generate(&tiny_graph(1), 42, 1000.0, 24, 3)
}

fn group_for(
    g: &hifuse::graph::HeteroGraph,
    replicas: usize,
    pipeline: bool,
    frac: f64,
) -> ReplicaGroup<'_, SimBackend> {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let t = replica_thread_budget(4, replicas);
    let engines: Vec<SimBackend> =
        (0..replicas).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    if frac > 0.0 {
        grp.attach_cache(Arc::new(ResidentStore::build(g, frac, 160, 42))).unwrap();
    }
    grp
}

fn serve_once(trace: &Trace, replicas: usize, pipeline: bool, frac: f64) -> ServeOutcome {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp = group_for(&g, replicas, pipeline, frac);
    serving::serve(&mut grp, trace, cfg().batch_size, WINDOW).unwrap()
}

/// The headline contract: one recorded trace, replayed across the full
/// grid, produces bitwise-identical per-request predictions and identical
/// coalescing decisions.
#[test]
fn replay_is_parallelism_invariant() {
    // Round-trip the schedule through the record/replay codec first, so
    // the grid below replays the *file*, not the in-memory generation.
    let recorded = test_trace();
    let path = std::env::temp_dir().join("hifuse_serve_parity_trace.bin");
    serving::trace::save(&recorded, &path).unwrap();
    let trace = serving::trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(trace, recorded, "codec round-trip changed the schedule");

    let reference = serve_once(&trace, 1, false, 0.0);
    assert_eq!(reference.predictions.len(), trace.requests.len());
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25] {
                let out = serve_once(&trace, replicas, pipeline, frac);
                assert_eq!(
                    out.batches, reference.batches,
                    "replicas={replicas} pipeline={pipeline} frac={frac}: \
                     coalescing diverged"
                );
                assert_eq!(
                    out.predictions, reference.predictions,
                    "replicas={replicas} pipeline={pipeline} frac={frac}: \
                     predictions diverged"
                );
            }
        }
    }
}

/// Serving keeps the zero-allocation steady state: after a warm-up pass,
/// repeated serves construct no buffer sets, grow nothing, and never miss
/// the backend arena — the producer pool cycles the same buffers.
#[test]
fn serve_steady_state_allocates_nothing() {
    for pipeline in [false, true] {
        let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp = group_for(&g, 2, pipeline, 0.25);
        let trace = test_trace();
        let snapshot = |grp: &ReplicaGroup<'_, SimBackend>| -> (u64, u64, u64, u64) {
            let arena: u64 =
                grp.engines().iter().map(|e| e.counters().borrow().arena.misses).sum();
            let p = grp.producer_stats();
            (arena, p.fresh, p.grown, p.reused)
        };
        serving::serve(&mut grp, &trace, cfg().batch_size, WINDOW).unwrap(); // warm-up
        let warm = snapshot(&grp);
        serving::serve(&mut grp, &trace, cfg().batch_size, WINDOW).unwrap();
        let steady = snapshot(&grp);
        assert_eq!(
            steady.0, warm.0,
            "pipeline {pipeline}: steady-state serve missed the arena"
        );
        assert_eq!(
            steady.1, warm.1,
            "pipeline {pipeline}: steady-state serve constructed a buffer set"
        );
        assert_eq!(
            steady.2, warm.2,
            "pipeline {pipeline}: steady-state serve grew a pooled buffer"
        );
        assert!(
            steady.3 > warm.3,
            "pipeline {pipeline}: steady-state serve never reused the pool"
        );
    }
}

/// Histogram well-formedness: percentiles are ordered, every request is
/// accounted for exactly once, and every latency is non-negative virtual
/// ticks measured from the request's own arrival.
#[test]
fn histogram_is_well_formed() {
    let trace = test_trace();
    let out = serve_once(&trace, 2, true, 0.0);
    let h = &out.hist;
    assert_eq!(h.count(), trace.requests.len() as u64);
    let (p50, p95, p99) = (h.percentile(50.0), h.percentile(95.0), h.percentile(99.0));
    assert!(p50 <= p95 && p95 <= p99, "percentiles out of order: {p50} {p95} {p99}");
    assert_eq!(out.latencies.len(), trace.requests.len());
    // Every prediction row block matches its request's seed count, and the
    // batches partition the request set exactly once.
    let mut seen = vec![0u32; trace.requests.len()];
    for b in &out.batches {
        for m in &b.members {
            seen[m.req] += 1;
            assert_eq!(m.len, trace.requests[m.req].seeds.len());
        }
    }
    assert!(seen.iter().all(|&c| c == 1), "coalescing lost or duplicated a request");
    for (r, p) in trace.requests.iter().zip(&out.predictions) {
        let t = p.served().expect("unbounded serve sheds nothing");
        assert_eq!(t.shape()[0], r.seeds.len(), "prediction rows != request seeds");
    }
}

/// Shared-vertex demux: two requests naming the same seed vertex inside
/// one batch get the same logit row back (the sampler dedups the vertex
/// into one slot; the demux fans it back out per request).
#[test]
fn duplicate_seeds_share_one_slot_row() {
    let g = tiny_graph(1);
    let v = g.train_idx[0];
    let w = g.train_idx[1];
    let trace = Trace {
        requests: vec![
            serving::Request { id: 0, arrival_tick: 10, seeds: vec![v, w] },
            serving::Request { id: 1, arrival_tick: 20, seeds: vec![v] },
        ],
    };
    let out = serve_once(&trace, 1, false, 0.0);
    assert_eq!(out.batches.len(), 1, "both requests fit one window and batch");
    let ta = out.predictions[0].served().unwrap();
    let tb = out.predictions[1].served().unwrap();
    let a = ta.as_f32().unwrap();
    let b = tb.as_f32().unwrap();
    let c = tb.shape()[1];
    assert_eq!(&a[..c], b, "the shared vertex must produce identical rows");
    assert_ne!(&a[c..], b, "distinct vertices should (generically) differ");
}
