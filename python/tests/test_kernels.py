"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, index patterns and padding masks; every
case asserts allclose between the interpret-mode Pallas kernel and ref.py.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.aggregate import agg_mean_merged, agg_mean_merged_bwd
from compile.kernels.attention import att_agg_merged
from compile import model

DIMS = st.tuples(
    st.integers(1, 6),    # R
    st.integers(2, 24),   # NS
    st.integers(1, 32),   # EP
    st.integers(1, 16),   # F
)


def _case(rng, r, ns, ep, f, dtype=np.float32):
    feat = rng.normal(size=(r, ns, f)).astype(dtype)
    src = rng.integers(0, ns, size=(r, ep)).astype(np.int32)
    dst = rng.integers(0, ns, size=(r, ep)).astype(np.int32)
    valid = (rng.random((r, ep)) < 0.75).astype(dtype)
    return feat, src, dst, valid


class TestMergedMean:
    @settings(max_examples=25, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_fwd_matches_ref(self, dims, seed):
        rng = np.random.default_rng(seed)
        feat, src, dst, valid = _case(rng, *dims)
        out = agg_mean_merged(feat, src, dst, valid)
        exp = ref.agg_mean_merged_ref(feat, src, dst, valid)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_bwd_matches_ref(self, dims, seed):
        rng = np.random.default_rng(seed)
        feat, src, dst, valid = _case(rng, *dims)
        dout = rng.normal(size=feat.shape).astype(np.float32)
        out = agg_mean_merged_bwd(src, dst, valid, dout)
        exp = ref.agg_mean_merged_bwd_ref(feat, src, dst, valid, dout)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)

    def test_all_invalid_edges_give_zero(self):
        rng = np.random.default_rng(0)
        feat, src, dst, valid = _case(rng, 3, 8, 10, 4)
        out = agg_mean_merged(feat, src, dst, np.zeros_like(valid))
        assert np.all(np.asarray(out) == 0.0)
        assert not np.any(np.isnan(np.asarray(out)))

    def test_single_edge_copies_source_row(self):
        ns, f = 8, 4
        feat = np.zeros((1, ns, f), np.float32)
        feat[0, 3] = np.arange(f, dtype=np.float32) + 1
        src = np.zeros((1, 1), np.int32) + 3
        dst = np.zeros((1, 1), np.int32) + 5
        valid = np.ones((1, 1), np.float32)
        out = np.asarray(agg_mean_merged(feat, src, dst, valid)).copy()
        np.testing.assert_allclose(out[0, 5], feat[0, 3])
        out[0, 5] = 0
        assert np.all(out == 0)

    def test_mean_divides_by_degree(self):
        # Two valid edges into the same dst: mean of the two source rows.
        feat = np.zeros((1, 4, 2), np.float32)
        feat[0, 0] = [2.0, 4.0]
        feat[0, 1] = [4.0, 8.0]
        src = np.array([[0, 1]], np.int32)
        dst = np.array([[2, 2]], np.int32)
        valid = np.ones((1, 2), np.float32)
        out = np.asarray(agg_mean_merged(feat, src, dst, valid))
        np.testing.assert_allclose(out[0, 2], [3.0, 6.0])

    def test_bf16_runs_and_is_close(self):
        rng = np.random.default_rng(1)
        feat, src, dst, valid = _case(rng, 2, 8, 12, 4)
        out = agg_mean_merged(jnp.asarray(feat, jnp.bfloat16), src, dst,
                              jnp.asarray(valid, jnp.bfloat16))
        exp = ref.agg_mean_merged_ref(feat, src, dst, valid)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out, np.float32), exp,
                                   rtol=5e-2, atol=5e-2)

    @settings(max_examples=10, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_mxu_formulation_matches_scatter(self, dims, seed):
        # The one-hot-matmul (TPU/MXU) body and the scatter body are two
        # lowerings of the same kernel; they must agree bit-for-bit-ish.
        rng = np.random.default_rng(seed)
        feat, src, dst, valid = _case(rng, *dims)
        a = agg_mean_merged(feat, src, dst, valid, mxu=False)
        b = agg_mean_merged(feat, src, dst, valid, mxu=True)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        dout = rng.normal(size=feat.shape).astype(np.float32)
        ga = agg_mean_merged_bwd(src, dst, valid, dout, mxu=False)
        gb = agg_mean_merged_bwd(src, dst, valid, dout, mxu=True)
        np.testing.assert_allclose(ga, gb, rtol=1e-5, atol=1e-5)

    def test_linearity_in_features(self):
        # Mean aggregation is linear in feat: agg(a*x + b*y) = a*agg(x)+b*agg(y)
        rng = np.random.default_rng(2)
        feat, src, dst, valid = _case(rng, 2, 10, 16, 4)
        feat2 = rng.normal(size=feat.shape).astype(np.float32)
        lhs = agg_mean_merged(2.0 * feat + 3.0 * feat2, src, dst, valid)
        rhs = (2.0 * agg_mean_merged(feat, src, dst, valid)
               + 3.0 * agg_mean_merged(feat2, src, dst, valid))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


class TestMergedAttention:
    @settings(max_examples=20, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_fwd_matches_ref(self, dims, seed):
        rng = np.random.default_rng(seed)
        fs, src, dst, valid = _case(rng, *dims)
        r, ns, f = fs.shape
        fd = rng.normal(size=fs.shape).astype(np.float32)
        a_s = rng.normal(size=(r, f)).astype(np.float32)
        a_d = rng.normal(size=(r, f)).astype(np.float32)
        out = att_agg_merged(fs, fd, a_s, a_d, src, dst, valid)
        exp = ref.att_agg_merged_ref(fs, fd, a_s, a_d, src, dst, valid)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_attention_weights_sum_to_one(self):
        # With identical source rows, attention output == the common row
        # (softmax weights sum to 1 regardless of scores).
        r, ns, ep, f = 1, 6, 8, 4
        rng = np.random.default_rng(3)
        row = rng.normal(size=(f,)).astype(np.float32)
        fs = np.broadcast_to(row, (r, ns, f)).copy()
        fd = rng.normal(size=(r, ns, f)).astype(np.float32)
        a_s = rng.normal(size=(r, f)).astype(np.float32)
        a_d = rng.normal(size=(r, f)).astype(np.float32)
        src = rng.integers(0, ns, size=(r, ep)).astype(np.int32)
        dst = np.full((r, ep), 2, np.int32)
        valid = np.ones((r, ep), np.float32)
        out = np.asarray(att_agg_merged(fs, fd, a_s, a_d, src, dst, valid))
        np.testing.assert_allclose(out[0, 2], row, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_att_mxu_matches_scatter(self, dims, seed):
        rng = np.random.default_rng(seed)
        fs, src, dst, valid = _case(rng, *dims)
        r, ns, f = fs.shape
        fd = rng.normal(size=fs.shape).astype(np.float32)
        a_s = rng.normal(size=(r, f)).astype(np.float32)
        a_d = rng.normal(size=(r, f)).astype(np.float32)
        a = att_agg_merged(fs, fd, a_s, a_d, src, dst, valid, mxu=False)
        b = att_agg_merged(fs, fd, a_s, a_d, src, dst, valid, mxu=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_no_nan_on_fully_padded_relation(self):
        rng = np.random.default_rng(4)
        fs, src, dst, valid = _case(rng, 3, 8, 10, 4)
        valid[1] = 0.0  # relation 1 entirely padding
        fd = rng.normal(size=fs.shape).astype(np.float32)
        a_s = rng.normal(size=(3, 4)).astype(np.float32)
        a_d = rng.normal(size=(3, 4)).astype(np.float32)
        out = np.asarray(att_agg_merged(fs, fd, a_s, a_d, src, dst, valid))
        assert not np.any(np.isnan(out))
        assert np.all(out[1] == 0.0)

    def test_merged_bwd_matches_per_relation_vjp(self):
        rng = np.random.default_rng(5)
        fs, src, dst, valid = _case(rng, 2, 8, 12, 4)
        fd = rng.normal(size=fs.shape).astype(np.float32)
        a_s = rng.normal(size=(2, 4)).astype(np.float32)
        a_d = rng.normal(size=(2, 4)).astype(np.float32)
        dout = rng.normal(size=fs.shape).astype(np.float32)
        g = model.att_merged_bwd(fs, fd, a_s, a_d, src, dst, valid, dout)
        for r in range(2):
            gr = model.att_agg_bwd(fs[r], fd[r], a_s[r], a_d[r], src[r],
                                   dst[r], valid[r], dout[r])
            for gm, gp in zip(g, gr):
                np.testing.assert_allclose(gm[r], gp, rtol=1e-4, atol=1e-4)


class TestNumericalGradients:
    def test_mean_bwd_is_true_vjp(self):
        # Finite-difference check of d<dout, agg(feat)>/dfeat.
        rng = np.random.default_rng(6)
        feat, src, dst, valid = _case(rng, 1, 6, 8, 3)
        dout = rng.normal(size=feat.shape).astype(np.float32)
        g = np.asarray(agg_mean_merged_bwd(src, dst, valid, dout))
        eps = 1e-3
        for _ in range(10):
            i = tuple(rng.integers(0, s) for s in feat.shape)
            fp, fm = feat.copy(), feat.copy()
            fp[i] += eps
            fm[i] -= eps
            lp = np.sum(np.asarray(agg_mean_merged(fp, src, dst, valid)) * dout)
            lm = np.sum(np.asarray(agg_mean_merged(fm, src, dst, valid)) * dout)
            np.testing.assert_allclose(g[i], (lp - lm) / (2 * eps),
                                       rtol=1e-2, atol=1e-2)
