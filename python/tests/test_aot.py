"""AOT emitter tests: manifest structure, module inventory, HLO text sanity.

Uses the already-emitted ``artifacts/tiny`` when present (``make artifacts``),
otherwise emits it into a tmp dir (slow path, still < 1 min).
"""

import os

import pytest

from compile import aot
from compile.profiles import PROFILES, elp

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    if os.path.exists(os.path.join(ART, "manifest.txt")):
        return ART
    out = tmp_path_factory.mktemp("artifacts")
    aot.emit_profile("tiny", str(out))
    return os.path.join(str(out), "tiny")


def parse_manifest(path):
    consts, modules = {}, {}
    cur = None
    with open(path) as fh:
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            if parts[0] == "const":
                consts[parts[1]] = int(parts[2])
            elif parts[0] == "module":
                cur = {"args": [], "rets": [], "file": None}
                modules[parts[1]] = cur
            elif parts[0] == "arg":
                cur["args"].append((parts[1], parts[2], parts[3]))
            elif parts[0] == "ret":
                cur["rets"].append((parts[1], parts[2], parts[3]))
            elif parts[0] == "file":
                cur["file"] = parts[1]
    return consts, modules


EXPECTED_MODULES = [
    "edge_select", "head",
    "proj_fwd_l0", "proj_fwd_l1", "proj_bwd_l0", "proj_bwd_l1",
    "proj_stacked_fwd_l0", "proj_stacked_fwd_l1",
    "proj_stacked_bwd_l0", "proj_stacked_bwd_l1",
    "agg_mean_fwd_h", "agg_mean_fwd_c", "agg_mean_bwd_h", "agg_mean_bwd_c",
    "agg_merged_fwd_h", "agg_merged_fwd_c",
    "agg_merged_bwd_h", "agg_merged_bwd_c",
    "att_agg_fwd_h", "att_agg_fwd_c", "att_agg_bwd_h", "att_agg_bwd_c",
    "att_merged_fwd_h", "att_merged_fwd_c",
    "att_merged_bwd_h", "att_merged_bwd_c",
    "fuse_relu_fwd_h", "fuse_relu_bwd_h", "fuse_lin_fwd_c", "fuse_lin_bwd_c",
]


def test_profiles_cover_all_datasets():
    # RPAD must cover the largest relation count (bgs: 122) and TPAD the
    # largest type count (bgs: 27) from the paper's Table 2.
    b = PROFILES["bench"]
    assert b["RPAD"] >= 122 and b["TPAD"] >= 27
    assert elp(b) == b["RPAD"] * b["EP"]


def test_manifest_complete(tiny_dir):
    consts, modules = parse_manifest(os.path.join(tiny_dir, "manifest.txt"))
    for k in ("NS", "EP", "RPAD", "TPAD", "F", "H", "C", "ELP"):
        assert k in consts, k
    for m in EXPECTED_MODULES:
        assert m in modules, f"missing module {m}"
        assert modules[m]["file"], m
        assert os.path.exists(os.path.join(tiny_dir, modules[m]["file"])), m


def test_manifest_shapes_match_profile(tiny_dir):
    consts, modules = parse_manifest(os.path.join(tiny_dir, "manifest.txt"))
    ns, ep, rp = consts["NS"], consts["EP"], consts["RPAD"]
    h = consts["H"]
    agg = modules["agg_merged_fwd_h"]
    assert agg["args"][0] == ("feat", "f32", f"{rp},{ns},{h}")
    assert agg["args"][1] == ("src", "i32", f"{rp},{ep}")
    assert agg["rets"][0][2] == f"{rp},{ns},{h}"
    sel = modules["edge_select"]
    assert sel["args"][0] == ("edge_type", "i32", str(consts["ELP"]))
    assert sel["args"][1][2] == "-"  # scalar
    assert len(sel["rets"]) == 2


def test_hlo_text_is_parseable_prelude(tiny_dir):
    # HLO text always begins with `HloModule`; a serialized proto would not.
    # (Guards against regressions to .serialize(), which xla 0.5.1 rejects.)
    for name in ("edge_select", "agg_merged_fwd_h", "head"):
        with open(os.path.join(tiny_dir, f"{name}.hlo.txt")) as fh:
            head_ = fh.read(64)
        assert head_.startswith("HloModule"), name


def test_multi_output_modules_declare_all_returns(tiny_dir):
    _, modules = parse_manifest(os.path.join(tiny_dir, "manifest.txt"))
    assert len(modules["head"]["rets"]) == 3
    assert len(modules["proj_bwd_l0"]["rets"]) == 2
    assert len(modules["att_merged_bwd_h"]["rets"]) == 4
