import os
import sys

# Make the build-time package importable when pytest runs from the repo
# root (the canonical `pytest python/tests/` invocation).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
