"""L2 stage-function tests: shapes, math identities, and a full train-step
composition check (chained stage functions == monolithic jax.grad model)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


class TestEdgeSelect:
    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 200), ntypes=st.integers(1, 12),
           rel=st.integers(0, 11), seed=st.integers(0, 2**31 - 1))
    def test_matches_numpy_oracle(self, n, ntypes, rel, seed):
        rng = np.random.default_rng(seed)
        et = rng.integers(0, ntypes, size=n).astype(np.int32)
        pos, count = model.edge_select(et, np.int32(rel))
        exp = np.where(et == rel)[0]
        assert int(count) == len(exp)
        np.testing.assert_array_equal(np.asarray(pos)[: len(exp)], exp)
        assert np.all(np.asarray(pos)[len(exp):] == n)

    def test_empty_selection(self):
        et = np.zeros(16, np.int32)
        pos, count = model.edge_select(et, np.int32(5))
        assert int(count) == 0
        assert np.all(np.asarray(pos) == 16)

    def test_positions_are_sorted_stable(self):
        et = np.array([1, 0, 1, 1, 0, 1], np.int32)
        pos, count = model.edge_select(et, np.int32(1))
        np.testing.assert_array_equal(np.asarray(pos)[:4], [0, 2, 3, 5])


class TestProjection:
    def test_proj_and_bwd(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8, 4)).astype(np.float32)
        w = rng.normal(size=(4, 6)).astype(np.float32)
        dy = rng.normal(size=(8, 6)).astype(np.float32)
        np.testing.assert_allclose(model.proj(x, w), x @ w, rtol=1e-5)
        dx, dw = model.proj_bwd(x, w, dy)
        np.testing.assert_allclose(dx, dy @ w.T, rtol=1e-5)
        np.testing.assert_allclose(dw, x.T @ dy, rtol=1e-5)

    def test_stacked_matches_per_relation(self):
        rng = np.random.default_rng(1)
        tp, rp, ns, fin, fout = 3, 5, 6, 4, 7
        xs = rng.normal(size=(tp, ns, fin)).astype(np.float32)
        w = rng.normal(size=(rp, fin, fout)).astype(np.float32)
        st_ = rng.integers(0, tp, size=rp).astype(np.int32)
        y = np.asarray(model.proj_stacked(xs, w, st_))
        for r in range(rp):
            np.testing.assert_allclose(y[r], xs[st_[r]] @ w[r], rtol=1e-4,
                                       atol=1e-5)

    def test_stacked_bwd_matches_autodiff(self):
        rng = np.random.default_rng(2)
        tp, rp, ns, fin, fout = 2, 4, 5, 3, 6
        xs = rng.normal(size=(tp, ns, fin)).astype(np.float32)
        w = rng.normal(size=(rp, fin, fout)).astype(np.float32)
        st_ = rng.integers(0, tp, size=rp).astype(np.int32)
        dy = rng.normal(size=(rp, ns, fout)).astype(np.float32)
        dxs, dw = model.proj_stacked_bwd(xs, w, st_, dy)
        f = lambda a, b: jnp.sum(model.proj_stacked(a, b, st_) * dy)
        exp_dxs, exp_dw = jax.grad(f, argnums=(0, 1))(xs, w)
        np.testing.assert_allclose(dxs, exp_dxs, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw, exp_dw, rtol=1e-4, atol=1e-5)


class TestFusion:
    def test_fuse_relu_is_segment_sum(self):
        rng = np.random.default_rng(3)
        tp, rp, ns, f = 3, 4, 5, 2
        dst_type = rng.integers(0, tp, size=rp).astype(np.int32)
        agg = rng.normal(size=(rp, ns, f)).astype(np.float32)
        out = np.asarray(model.fuse_relu(dst_type, agg, tp))
        m = np.zeros((tp, rp), np.float32)
        m[dst_type, np.arange(rp)] = 1.0
        exp = np.maximum(np.einsum("tr,rnf->tnf", m, agg), 0.0)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-6)

    def test_fuse_bwds_match_autodiff(self):
        rng = np.random.default_rng(4)
        tp, rp, ns, f = 2, 3, 4, 2
        dst_type = rng.integers(0, tp, size=rp).astype(np.int32)
        agg = rng.normal(size=(rp, ns, f)).astype(np.float32)
        dout = rng.normal(size=(tp, ns, f)).astype(np.float32)
        for fwd, bwd in ((model.fuse_relu, model.fuse_relu_bwd),
                         (model.fuse_lin, model.fuse_lin_bwd)):
            got = bwd(dst_type, agg, dout, tp)
            exp = jax.grad(lambda a: jnp.sum(fwd(dst_type, a, tp) * dout))(agg)
            np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-5)


class TestHead:
    def test_loss_and_grad_match_autodiff(self):
        rng = np.random.default_rng(5)
        ns, c = 10, 4
        logits = rng.normal(size=(ns, c)).astype(np.float32)
        labels = rng.integers(0, c, size=ns).astype(np.int32)
        mask = (rng.random(ns) < 0.5).astype(np.float32)
        mask[0] = 1.0
        loss, dlogits, ncorr = model.head(logits, labels, mask)

        def ce(lg):
            z = lg - jax.scipy.special.logsumexp(lg, axis=1, keepdims=True)
            oh = jax.nn.one_hot(labels, c)
            return -jnp.sum(jnp.sum(z * oh, 1) * mask) / jnp.maximum(mask.sum(), 1.0)

        np.testing.assert_allclose(loss, ce(logits), rtol=1e-5)
        np.testing.assert_allclose(dlogits, jax.grad(ce)(logits), rtol=1e-4,
                                   atol=1e-6)
        pred = np.argmax(logits, 1)
        np.testing.assert_allclose(ncorr, np.sum((pred == labels) * mask))

    def test_perfect_logits_give_zero_grad_direction(self):
        ns, c = 4, 3
        labels = np.array([0, 1, 2, 0], np.int32)
        logits = np.full((ns, c), -100.0, np.float32)
        logits[np.arange(ns), labels] = 100.0
        mask = np.ones(ns, np.float32)
        loss, dlogits, ncorr = model.head(logits, labels, mask)
        assert float(loss) < 1e-3
        assert float(ncorr) == ns
        np.testing.assert_allclose(np.asarray(dlogits), 0.0, atol=1e-6)


def _rand_batch(rng, tp, rp, ns, ep, f):
    """Random but structurally valid mini-batch for composition tests."""
    xs = rng.normal(size=(tp, ns, f)).astype(np.float32)
    src_type = rng.integers(0, tp, size=rp).astype(np.int32)
    dst_type = rng.integers(0, tp, size=rp).astype(np.int32)
    src = rng.integers(0, ns, size=(2, rp, ep)).astype(np.int32)
    dst = rng.integers(0, ns, size=(2, rp, ep)).astype(np.int32)
    valid = (rng.random((2, rp, ep)) < 0.7).astype(np.float32)
    return xs, src_type, dst_type, src, dst, valid


class TestTrainStepComposition:
    """Chained stage modules == monolithic jax model. This validates that the
    Rust coordinator's module chaining computes the true RGCN gradient."""

    def test_rgcn_two_layer_forward_and_grads(self):
        rng = np.random.default_rng(7)
        tp, rp, ns, ep, f, h, c = 3, 5, 8, 12, 4, 6, 3
        xs, src_type, dst_type, src, dst, valid = _rand_batch(
            rng, tp, rp, ns, ep, f)
        w0 = (rng.normal(size=(rp, f, h)) * 0.3).astype(np.float32)
        w1 = (rng.normal(size=(rp, h, c)) * 0.3).astype(np.float32)
        labels = rng.integers(0, c, size=ns).astype(np.int32)
        mask = np.zeros(ns, np.float32)
        mask[:3] = 1.0
        seed_t = 0

        def monolithic(w0_, w1_):
            p0 = jnp.stack([xs[src_type[r]] @ w0_[r] for r in range(rp)])
            a0 = ref.agg_mean_merged_ref(p0, src[0], dst[0], valid[0])
            h1 = model.fuse_relu(dst_type, a0, tp)
            p1 = jnp.stack([h1[src_type[r]] @ w1_[r] for r in range(rp)])
            a1 = ref.agg_mean_merged_ref(p1, src[1], dst[1], valid[1])
            h2 = model.fuse_lin(dst_type, a1, tp)
            return model.head(h2[seed_t], labels, mask)[0]

        # --- staged execution, the way the Rust coordinator chains modules
        p0 = np.stack([np.asarray(model.proj(xs[src_type[r]], w0[r]))
                       for r in range(rp)])
        a0 = np.asarray(model.agg_merged(p0, src[0], dst[0], valid[0]))
        h1 = np.asarray(model.fuse_relu(dst_type, a0, tp))
        p1 = np.stack([np.asarray(model.proj(h1[src_type[r]], w1[r]))
                       for r in range(rp)])
        a1 = np.asarray(model.agg_merged(p1, src[1], dst[1], valid[1]))
        h2 = np.asarray(model.fuse_lin(dst_type, a1, tp))
        loss, dlogits, _ = model.head(h2[seed_t], labels, mask)

        np.testing.assert_allclose(loss, monolithic(w0, w1), rtol=1e-4)

        # backward chain
        dh2 = np.zeros_like(h2)
        dh2[seed_t] = np.asarray(dlogits)
        da1 = np.asarray(model.fuse_lin_bwd(dst_type, a1, dh2, tp))
        dp1 = np.asarray(model.agg_merged_bwd(src[1], dst[1], valid[1], da1))
        dh1 = np.zeros_like(h1)
        dw1 = np.zeros_like(w1)
        for r in range(rp):
            dx, dwr = model.proj_bwd(h1[src_type[r]], w1[r], dp1[r])
            dh1[src_type[r]] += np.asarray(dx)
            dw1[r] = np.asarray(dwr)
        da0 = np.asarray(model.fuse_relu_bwd(dst_type, a0, dh1, tp))
        dp0 = np.asarray(model.agg_merged_bwd(src[0], dst[0], valid[0], da0))
        dw0 = np.zeros_like(w0)
        for r in range(rp):
            _, dwr = model.proj_bwd(xs[src_type[r]], w0[r], dp0[r])
            dw0[r] = np.asarray(dwr)

        exp_dw0, exp_dw1 = jax.grad(monolithic, argnums=(0, 1))(w0, w1)
        np.testing.assert_allclose(dw0, exp_dw0, rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(dw1, exp_dw1, rtol=1e-3, atol=1e-5)

    def test_rgat_layer_grads_via_staged_bwd(self):
        rng = np.random.default_rng(8)
        rp, ns, ep, f = 3, 8, 10, 4
        fs = rng.normal(size=(rp, ns, f)).astype(np.float32)
        fd = rng.normal(size=(rp, ns, f)).astype(np.float32)
        a_s = rng.normal(size=(rp, f)).astype(np.float32)
        a_d = rng.normal(size=(rp, f)).astype(np.float32)
        src = rng.integers(0, ns, size=(rp, ep)).astype(np.int32)
        dst = rng.integers(0, ns, size=(rp, ep)).astype(np.int32)
        valid = (rng.random((rp, ep)) < 0.7).astype(np.float32)
        dout = rng.normal(size=(rp, ns, f)).astype(np.float32)
        got = model.att_merged_bwd(fs, fd, a_s, a_d, src, dst, valid, dout)
        fn = lambda a, b, c_, d: jnp.sum(
            ref.att_agg_merged_ref(a, b, c_, d, src, dst, valid) * dout)
        exp = jax.grad(fn, argnums=(0, 1, 2, 3))(fs, fd, a_s, a_d)
        for g, e in zip(got, exp):
            np.testing.assert_allclose(g, e, rtol=1e-3, atol=1e-5)
