"""L2: stage functions of the 2-layer RGCN / RGAT mini-batch training step.

Each function below becomes one AOT-compiled HLO module (plus a VJP module
where the backward pass needs it). The Rust coordinator (L3) chains these
modules per its execution plan — per-relation loops for the PyG-style
baseline, merged single launches for HiFuse (DESIGN.md §3).

Model math (per layer l, relations r: src_type s_r -> dst_type d_r):

    p_r = h[s_r] @ W_r                       feature projection
    a_r = Aggregate_r(p_r)                   neighbor aggregation
          RGCN: per-dst mean  |  RGAT: edge-softmax attention
    h'  = act( sum_{r: d_r = t} a_r )        semantic fusion (per type t)

followed by softmax cross-entropy on the seed rows of the target type.
Backward modules recompute the forward internally (rematerialization) so no
residual tensors cross module boundaries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.aggregate import agg_mean_merged, agg_mean_merged_bwd
from .kernels.attention import att_agg_merged


# --------------------------------------------------------------------------
# Semantic-graph build: edge index selection (the paper's Algorithm 2).
# Baseline runs this as GPU modules (the 'compare' + 'index_select' CUDA
# kernels); HiFuse moves it to CPU threads in rust/src/semantic/.
# --------------------------------------------------------------------------

def edge_select(edge_type, rel):
    """Select positions of edges whose type == rel from a tagged edge list.

    edge_type: [ELP] i32; rel: scalar i32.
    Returns (pos [ELP] i32, count i32): pos[:count] = ascending positions of
    matching edges; pos[count:] = ELP (sentinel). Static shapes via a
    sort-based stable compaction (XLA cannot return dynamic sizes).
    NOTE (EXPERIMENTS.md §Perf #3): an O(E) cumsum-scatter compaction was
    tried and reverted — `cumsum` lowers to a quadratic reduce-window on
    this CPU backend (340 ms/call vs the sort's 2.2 ms).
    """
    elp_ = edge_type.shape[0]
    mask = edge_type == rel
    iota = jnp.arange(elp_, dtype=jnp.int32)
    pos = jnp.sort(jnp.where(mask, iota, jnp.int32(elp_)))
    count = jnp.sum(mask.astype(jnp.int32))
    return pos, count


# --------------------------------------------------------------------------
# On-device feature collection (cache path, DESIGN.md §7).
# --------------------------------------------------------------------------

def feature_gather(cache, miss, idx):
    """Assemble the fused [TPAD, NS, F] batch slab from the device-resident
    cache rows, the (partially) uploaded miss rows, and per-slot scatter
    indices: idx >= 0 reads cache row idx; idx == -1 writes a zero padding
    row; idx <= -2 reads miss row (-idx - 2).

    cache: [CSLOTS, F] f32; miss: [TPAD*NS, F] f32; idx: [TPAD, NS] i32.
    Forward-only (VJP-free): the raw-feature slab is never differentiated.
    """
    tp, ns = idx.shape
    f = cache.shape[1]
    flat = idx.reshape(-1)
    hit_rows = jnp.take(cache, jnp.clip(flat, 0, cache.shape[0] - 1), axis=0)
    miss_rows = jnp.take(miss, jnp.clip(-flat - 2, 0, miss.shape[0] - 1), axis=0)
    sel = flat[:, None]
    out = jnp.where(sel >= 0, hit_rows, jnp.where(sel <= -2, miss_rows, 0.0))
    return out.reshape(tp, ns, f)


# --------------------------------------------------------------------------
# Feature projection.
# --------------------------------------------------------------------------

def proj(x, w):
    """Per-relation projection: [NS, Fin] @ [Fin, Fout] -> [NS, Fout]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def proj_stacked(xs, w, src_type):
    """All-relations projection in one launch (extension config `R+M+S`,
    DESIGN.md §3): gather each relation's source-type slab, batched matmul.

    xs: [TPAD, NS, Fin]; w: [RPAD, Fin, Fout]; src_type: [RPAD] i32.
    Returns [RPAD, NS, Fout].
    """
    gathered = xs[src_type]  # [RPAD, NS, Fin]
    return jnp.einsum("rni,rio->rno", gathered, w,
                      preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# Neighbor aggregation. Per-relation forms come from ref.py (they model the
# PyG scatter/gather kernels); merged forms are the L1 Pallas kernels.
# --------------------------------------------------------------------------

agg_mean = ref.agg_mean_ref
agg_mean_bwd = ref.agg_mean_bwd_ref
att_agg = ref.att_agg_ref
agg_merged = agg_mean_merged
agg_merged_bwd = agg_mean_merged_bwd
att_merged = att_agg_merged


def att_agg_bwd(feat_src, feat_dst, a_src, a_dst, src, dst, valid, dout):
    """VJP of the per-relation attention aggregation w.r.t.
    (feat_src, feat_dst, a_src, a_dst); recomputes forward internally."""
    _, vjp = jax.vjp(
        lambda fs, fd, as_, ad: ref.att_agg_ref(fs, fd, as_, ad, src, dst, valid),
        feat_src, feat_dst, a_src, a_dst)
    return vjp(dout)


def att_merged_bwd(feat_src, feat_dst, a_src, a_dst, src, dst, valid, dout):
    """VJP of the merged attention aggregation (one launch for all R)."""
    _, vjp = jax.vjp(
        lambda fs, fd, as_, ad: ref.att_agg_merged_ref(fs, fd, as_, ad, src,
                                                       dst, valid),
        feat_src, feat_dst, a_src, a_dst)
    return vjp(dout)


# --------------------------------------------------------------------------
# Semantic fusion: per-type sum of the relation results that target the type.
# dst_type[r] is the destination vertex type of relation r. Implemented as a
# segment scatter-add over relations (O(RPAD*NS*Fd)); the earlier dense
# [TPAD,RPAD] incidence-matrix einsum did TPAD x more work and was the #2
# hot spot of every execution mode (EXPERIMENTS.md §Perf #4).
# --------------------------------------------------------------------------

def fuse_relu(dst_type, agg, tpad):
    """Hidden-layer fusion: out[t] = ReLU(sum_{r: dst_type[r]=t} agg[r]).

    dst_type: [RPAD] i32; agg: [RPAD, NS, Fd] -> [TPAD, NS, Fd].
    Padded relations must carry zero rows in `agg` (they do: no valid
    edges -> aggregation emits zeros), so their scatter-add is a no-op."""
    s = jnp.zeros((tpad,) + agg.shape[1:], agg.dtype).at[dst_type].add(agg)
    return jax.nn.relu(s)


def fuse_lin(dst_type, agg, tpad):
    """Output-layer fusion (logits): no activation."""
    return jnp.zeros((tpad,) + agg.shape[1:], agg.dtype).at[dst_type].add(agg)


def fuse_relu_bwd(dst_type, agg, dout, tpad):
    """VJP w.r.t. agg: dagg[r] = dout[dst_type[r]] * relu-mask (recomputed)."""
    _, vjp = jax.vjp(lambda a: fuse_relu(dst_type, a, tpad), agg)
    return vjp(dout)[0]


def fuse_lin_bwd(dst_type, agg, dout, tpad):
    _, vjp = jax.vjp(lambda a: fuse_lin(dst_type, a, tpad), agg)
    return vjp(dout)[0]


# --------------------------------------------------------------------------
# Head: softmax cross-entropy loss + gradient + accuracy in one module.
# --------------------------------------------------------------------------

def head(logits, labels, seed_mask):
    """logits: [NS, C]; labels: [NS] i32; seed_mask: [NS] f32 (1 on seed
    rows). Returns (loss scalar, dlogits [NS, C], ncorrect scalar)."""
    c = logits.shape[1]
    z = logits - jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    onehot = (labels[:, None] == jnp.arange(c, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(logits.dtype)
    n = jnp.maximum(jnp.sum(seed_mask), 1.0)
    loss = -jnp.sum(jnp.sum(z * onehot, axis=1) * seed_mask) / n
    dlogits = (jnp.exp(z) - onehot) * seed_mask[:, None] / n
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    ncorrect = jnp.sum((pred == labels).astype(logits.dtype) * seed_mask)
    return loss, dlogits, ncorrect


# --------------------------------------------------------------------------
# Generic projection backward (shared by per-relation and stacked forms).
# --------------------------------------------------------------------------

def proj_bwd(x, w, dy):
    """VJP of ``proj``: returns (dx, dw)."""
    _, vjp = jax.vjp(lambda a, b: proj(a, b), x, w)
    return vjp(dy)


def proj_stacked_bwd(xs, w, src_type, dy):
    """VJP of ``proj_stacked`` w.r.t. (xs, w)."""
    _, vjp = jax.vjp(lambda a, b: proj_stacked(a, b, src_type), xs, w)
    return vjp(dy)


def proj_resident_bwd(xs, w, src_type, dy, dhin_acc):
    """``proj_stacked_bwd`` with a device-resident accumulator: returns
    (dhin_acc + dxs, dw) so the two RGAT endpoint passes chain on-device
    instead of staging partial sums on the host (DESIGN.md §7)."""
    dxs, dw = proj_stacked_bwd(xs, w, src_type, dy)
    return dhin_acc + dxs, dw


# --------------------------------------------------------------------------
# Device-resident step seams: full-slab head, serve logits pick, fused SGD.
# --------------------------------------------------------------------------

def head_full(hout, labels, seed_mask, target_type):
    """``head`` over the full fused output: extracts the target-type slab
    on-device and scatters dlogits back into a [TPAD, NS, C] gradient, so
    only the two scalars ever leave the device.

    hout: [TPAD, NS, C]; target_type: scalar i32.
    Returns (loss scalar, dh2 [TPAD, NS, C], ncorrect scalar)."""
    logits = jax.lax.dynamic_index_in_dim(hout, target_type, axis=0,
                                          keepdims=False)
    loss, dlogits, ncorrect = head(logits, labels, seed_mask)
    dh2 = jnp.zeros_like(hout).at[target_type].set(dlogits)
    return loss, dh2, ncorrect


def slab_pick(hout, target_type):
    """Serve-path logits extraction: the device-side target-type slab copy.

    hout: [TPAD, NS, C]; target_type: scalar i32 -> [NS, C]."""
    return jax.lax.dynamic_index_in_dim(hout, target_type, axis=0,
                                        keepdims=False)


def sgd_rgcn(w0, w1, dw0, dw1, lr):
    """Fused on-device SGD over the RGCN parameter set: w -= lr * dw.

    The ``0.0 +`` fold mirrors the host path's accumulate-into-zeros
    (`Params::add_assign` on a `zeros_like`), which differs bitwise when a
    gradient element is -0.0 — required for trajectory identity."""
    return w0 - lr * (0.0 + dw0), w1 - lr * (0.0 + dw1)


def sgd_rgat(w0, w1, a_src0, a_dst0, a_src1, a_dst1,
             dw0_src, dw0_dst, dw1_src, dw1_dst,
             da_src0, da_dst0, da_src1, da_dst1, lr):
    """Fused on-device SGD over the RGAT parameter set. Projection weights
    fold their two endpoint-pass gradients (src then dst) before the
    update; attention vectors carry a single gradient each. The ``0.0 +``
    fold mirrors the host accumulate-into-zeros order (see sgd_rgcn)."""
    return (w0 - lr * ((0.0 + dw0_src) + dw0_dst),
            w1 - lr * ((0.0 + dw1_src) + dw1_dst),
            a_src0 - lr * da_src0,
            a_dst0 - lr * da_dst0,
            a_src1 - lr * da_src1,
            a_dst1 - lr * da_dst1)
