"""Shape profiles shared between the AOT emitter and the Rust coordinator.

All HLO modules have static shapes; the Rust coordinator pads mini-batches to
these buckets (DESIGN.md §6). Constants are exported into the artifact
manifest so Rust never hard-codes them.

  NS     node slots per vertex type (per-type slab rows)
  EP     edge slots per relation (per semantic graph)
  RPAD   padded relation count; >= max dataset relation count (am=108,
         bgs=122, aifb=104, mutag=50 -> 128 covers all four)
  TPAD   padded vertex-type count (bgs has 27 -> 32)
  F/H/C  raw-feature / hidden / class dims (2-layer RGCN & RGAT)
  ELP    merged edge-list length = RPAD*EP (edge-type tagged batch edge list
         over which the semantic-graph-build stage selects)
  CSLOTS device-resident feature-cache rows (DESIGN.md §7): capacity of the
         packed hot-vertex slab the feature_gather module reads; the
         --cache-frac budget is clamped to it
"""

PROFILES = {
    # CI / pytest / cargo-test profile: small enough that every module runs
    # in milliseconds under the CPU PJRT client.
    "tiny": dict(NS=32, EP=16, RPAD=8, TPAD=8, F=8, H=16, C=4, CSLOTS=160),
    # Benchmark profile used for all paper tables/figures: RPAD=128 >= every
    # dataset's relation count so one artifact set serves aifb/mutag/bgs/am.
    # C=16 >= am's 11 classes (largest label space in Table 2).
    "bench": dict(NS=512, EP=256, RPAD=128, TPAD=32, F=32, H=64, C=16, CSLOTS=8192),
}


def elp(p: dict) -> int:
    return p["RPAD"] * p["EP"]
