"""AOT emitter: lower every L2 stage function to HLO text + manifest.

Run once at build time (``make artifacts``); the Rust coordinator loads the
resulting ``artifacts/<profile>/*.hlo.txt`` via the PJRT C API and never
touches Python again.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowering converts the StableHLO
module to an XlaComputation with ``return_tuple=True``; the Rust side unwraps
the tuple.

Usage: python -m compile.aot --out-dir ../artifacts [--profiles tiny,bench]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .profiles import PROFILES, elp

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    return_tuple=False so single-output modules compile to an array-rooted
    HLO: the PJRT CPU client then returns a plain array buffer, which the
    Rust runtime can keep device-resident between dispatches (Engine::run_dev
    — EXPERIMENTS.md §Perf #5). Multi-output modules still get a tuple root
    (XLA requires a single root) and are decomposed host-side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def module_table(p):
    """(name, fn, [(argname, ShapeDtypeStruct), ...]) for one profile.

    Layer dims: l0 projects F->H (fusion: ReLU), l1 projects H->C (fusion:
    linear logits). ``_h``/``_c`` suffixes are the aggregation feature dims.
    """
    ns, ep, rp, tp = p["NS"], p["EP"], p["RPAD"], p["TPAD"]
    f, h, c = p["F"], p["H"], p["C"]
    cslots = p["CSLOTS"]
    el = elp(p)

    t = []

    def add(name, fn, *args):
        t.append((name, fn, list(args)))

    # -- semantic graph build (baseline-on-GPU path) ------------------------
    add("edge_select", model.edge_select,
        ("edge_type", spec((el,), I32)), ("rel", spec((), I32)))

    # -- on-device feature collection (cache path, DESIGN.md §7) ------------
    add("feature_gather", model.feature_gather,
        ("cache", spec((cslots, f))), ("miss", spec((tp * ns, f))),
        ("idx", spec((tp, ns), I32)))

    # -- feature projection -------------------------------------------------
    for l, (fin, fout) in (("l0", (f, h)), ("l1", (h, c))):
        add(f"proj_fwd_{l}", model.proj,
            ("x", spec((ns, fin))), ("w", spec((fin, fout))))
        add(f"proj_bwd_{l}", model.proj_bwd,
            ("x", spec((ns, fin))), ("w", spec((fin, fout))),
            ("dy", spec((ns, fout))))
        add(f"proj_stacked_fwd_{l}", model.proj_stacked,
            ("xs", spec((tp, ns, fin))), ("w", spec((rp, fin, fout))),
            ("src_type", spec((rp,), I32)))
        add(f"proj_stacked_bwd_{l}", model.proj_stacked_bwd,
            ("xs", spec((tp, ns, fin))), ("w", spec((rp, fin, fout))),
            ("src_type", spec((rp,), I32)), ("dy", spec((rp, ns, fout))))
        add(f"proj_resident_bwd_{l}", model.proj_resident_bwd,
            ("xs", spec((tp, ns, fin))), ("w", spec((rp, fin, fout))),
            ("src_type", spec((rp,), I32)), ("dy", spec((rp, ns, fout))),
            ("dhin_acc", spec((tp, ns, fin))))

    # -- neighbor aggregation (RGCN mean) -----------------------------------
    for sfx, fd in (("h", h), ("c", c)):
        add(f"agg_mean_fwd_{sfx}", model.agg_mean,
            ("feat", spec((ns, fd))), ("src", spec((ep,), I32)),
            ("dst", spec((ep,), I32)), ("valid", spec((ep,))))
        add(f"agg_mean_bwd_{sfx}", model.agg_mean_bwd,
            ("feat", spec((ns, fd))), ("src", spec((ep,), I32)),
            ("dst", spec((ep,), I32)), ("valid", spec((ep,))),
            ("dout", spec((ns, fd))))
        add(f"agg_merged_fwd_{sfx}", model.agg_merged,
            ("feat", spec((rp, ns, fd))), ("src", spec((rp, ep), I32)),
            ("dst", spec((rp, ep), I32)), ("valid", spec((rp, ep))))
        add(f"agg_merged_bwd_{sfx}", model.agg_merged_bwd,
            ("src", spec((rp, ep), I32)), ("dst", spec((rp, ep), I32)),
            ("valid", spec((rp, ep))), ("dout", spec((rp, ns, fd))))

    # -- neighbor aggregation (RGAT attention) ------------------------------
    for sfx, fd in (("h", h), ("c", c)):
        per = [("feat_src", spec((ns, fd))), ("feat_dst", spec((ns, fd))),
               ("a_src", spec((fd,))), ("a_dst", spec((fd,))),
               ("src", spec((ep,), I32)), ("dst", spec((ep,), I32)),
               ("valid", spec((ep,)))]
        add(f"att_agg_fwd_{sfx}", model.att_agg, *per)
        add(f"att_agg_bwd_{sfx}", model.att_agg_bwd, *per,
            ("dout", spec((ns, fd))))
        mrg = [("feat_src", spec((rp, ns, fd))), ("feat_dst", spec((rp, ns, fd))),
               ("a_src", spec((rp, fd))), ("a_dst", spec((rp, fd))),
               ("src", spec((rp, ep), I32)), ("dst", spec((rp, ep), I32)),
               ("valid", spec((rp, ep)))]
        add(f"att_merged_fwd_{sfx}", model.att_merged, *mrg)
        add(f"att_merged_bwd_{sfx}", model.att_merged_bwd, *mrg,
            ("dout", spec((rp, ns, fd))))

    # -- semantic fusion (dst_type-indexed segment scatter; tpad closed over)
    add("fuse_relu_fwd_h", lambda dt, agg: model.fuse_relu(dt, agg, tp),
        ("dst_type", spec((rp,), I32)), ("agg", spec((rp, ns, h))))
    add("fuse_relu_bwd_h", lambda dt, agg, dout: model.fuse_relu_bwd(dt, agg, dout, tp),
        ("dst_type", spec((rp,), I32)), ("agg", spec((rp, ns, h))),
        ("dout", spec((tp, ns, h))))
    add("fuse_lin_fwd_c", lambda dt, agg: model.fuse_lin(dt, agg, tp),
        ("dst_type", spec((rp,), I32)), ("agg", spec((rp, ns, c))))
    add("fuse_lin_bwd_c", lambda dt, agg, dout: model.fuse_lin_bwd(dt, agg, dout, tp),
        ("dst_type", spec((rp,), I32)), ("agg", spec((rp, ns, c))),
        ("dout", spec((tp, ns, c))))

    # -- head ----------------------------------------------------------------
    add("head", model.head,
        ("logits", spec((ns, c))), ("labels", spec((ns,), I32)),
        ("seed_mask", spec((ns,))))
    add("head_full", model.head_full,
        ("hout", spec((tp, ns, c))), ("labels", spec((ns,), I32)),
        ("seed_mask", spec((ns,))), ("target_type", spec((), I32)))
    add("slab_pick", model.slab_pick,
        ("hout", spec((tp, ns, c))), ("target_type", spec((), I32)))

    # -- on-device optimizer (device-resident mode, DESIGN.md §7) -----------
    add("sgd_rgcn", model.sgd_rgcn,
        ("w0", spec((rp, f, h))), ("w1", spec((rp, h, c))),
        ("dw0", spec((rp, f, h))), ("dw1", spec((rp, h, c))),
        ("lr", spec(())))
    add("sgd_rgat", model.sgd_rgat,
        ("w0", spec((rp, f, h))), ("w1", spec((rp, h, c))),
        ("a_src0", spec((rp, h))), ("a_dst0", spec((rp, h))),
        ("a_src1", spec((rp, c))), ("a_dst1", spec((rp, c))),
        ("dw0_src", spec((rp, f, h))), ("dw0_dst", spec((rp, f, h))),
        ("dw1_src", spec((rp, h, c))), ("dw1_dst", spec((rp, h, c))),
        ("da_src0", spec((rp, h))), ("da_dst0", spec((rp, h))),
        ("da_src1", spec((rp, c))), ("da_dst1", spec((rp, c))),
        ("lr", spec(())))

    return t


_DTYPE = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def _shape_str(s):
    return ",".join(str(d) for d in s.shape) if s.shape else "-"


def emit_profile(pname, out_root):
    p = PROFILES[pname]
    out_dir = os.path.join(out_root, pname)
    os.makedirs(out_dir, exist_ok=True)
    lines = [f"profile {pname}"]
    for k, v in p.items():
        lines.append(f"const {k} {v}")
    lines.append(f"const ELP {elp(p)}")

    for name, fn, args in module_table(p):
        specs = [s for _, s in args]
        # keep_unused=True: linear VJPs ignore some inputs (e.g. feat in the
        # mean-aggregation backward); the manifest interface must still match
        # the compiled parameter list exactly.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        outs = jax.eval_shape(fn, *specs)
        outs = outs if isinstance(outs, (tuple, list)) else (outs,)
        lines.append(f"module {name}")
        for an, s in args:
            lines.append(f"arg {an} {_DTYPE[s.dtype]} {_shape_str(s)}")
        for i, s in enumerate(outs):
            lines.append(f"ret out{i} {_DTYPE[s.dtype]} {_shape_str(s)}")
        lines.append(f"file {fname}")
        lines.append("end")
        print(f"[aot] {pname}/{name}: {len(text)} chars, "
              f"{len(args)} args -> {len(outs)} outs")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(lines) + "\n")
    print(f"[aot] wrote {out_dir}/manifest.txt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profiles", default="tiny,bench")
    args = ap.parse_args()
    for pname in args.profiles.split(","):
        emit_profile(pname.strip(), args.out_dir)


if __name__ == "__main__":
    main()
