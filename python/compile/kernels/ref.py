"""Pure-jnp reference oracles for the HiFuse aggregation kernels.

These are the CORE correctness signal for the Pallas kernels in
``aggregate.py`` / ``attention.py``: pytest asserts allclose between the
Pallas (interpret=True) outputs and these functions over hypothesis-driven
shape/value sweeps.

Conventions (shared with the Rust coordinator — see DESIGN.md §5):
  * Per-relation node slabs are padded to ``NS`` rows; invalid rows are zero.
  * Per-relation edge lists are padded to ``EP`` entries; padding edges have
    ``valid == 0`` and ``src == dst == 0`` (they must not contribute).
  * Merged tensors stack the relation axis first: ``feat[R, NS, F]``,
    ``src/dst/valid[R, EP]``.
  * Mean aggregation divides by ``max(1, degree)`` so isolated vertices
    produce zeros rather than NaNs (matches PyG's ``scatter(reduce='mean')``
    on empty rows).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.2  # GAT / RGAT LeakyReLU negative slope.
NEG_INF = -1e30  # Finite stand-in for -inf: keeps padded segments NaN-free.


# --------------------------------------------------------------------------
# Per-relation primitives (the "PyG scatter/gather kernel" equivalents).
# --------------------------------------------------------------------------

def agg_mean_ref(feat, src, dst, valid):
    """Mean-aggregate ``feat[src[e]]`` onto ``dst[e]`` for one relation.

    feat: [NS, F] float; src/dst: [EP] int32; valid: [EP] float (0/1).
    Returns [NS, F]: row j = mean over valid edges with dst == j.
    """
    ns = feat.shape[0]
    gathered = feat[src] * valid[:, None]  # [EP, F]
    sums = jnp.zeros((ns, feat.shape[1]), feat.dtype).at[dst].add(gathered)
    cnt = jnp.zeros((ns,), feat.dtype).at[dst].add(valid)
    return sums / jnp.maximum(cnt, 1.0)[:, None]


def agg_mean_bwd_ref(feat, src, dst, valid, dout):
    """VJP of :func:`agg_mean_ref` w.r.t. ``feat`` (linear, so exact)."""
    _, vjp = jax.vjp(lambda f: agg_mean_ref(f, src, dst, valid), feat)
    return vjp(dout)[0]


def att_agg_ref(feat_src, feat_dst, a_src, a_dst, src, dst, valid):
    """GAT-style attention aggregation for one relation.

    e_ij = LeakyReLU(a_src . h_i + a_dst . h_j)  for edge i->j,
    alpha = segment-softmax over incoming edges of j (valid edges only),
    out_j = sum_i alpha_ij h_i.

    feat_src/feat_dst: [NS, F]; a_src/a_dst: [F]; src/dst: [EP]; valid: [EP].
    """
    ns = feat_src.shape[0]
    es = feat_src @ a_src  # [NS]
    ed = feat_dst @ a_dst  # [NS]
    e = jax.nn.leaky_relu(es[src] + ed[dst], LEAKY_SLOPE)  # [EP]
    e = jnp.where(valid > 0, e, NEG_INF)
    seg_max = jnp.full((ns,), NEG_INF, feat_src.dtype).at[dst].max(e)
    w = jnp.exp(e - seg_max[dst]) * valid  # [EP]
    denom = jnp.zeros((ns,), feat_src.dtype).at[dst].add(w)
    num = jnp.zeros_like(feat_src).at[dst].add(w[:, None] * feat_src[src])
    return num / jnp.maximum(denom, 1e-16)[:, None]


# --------------------------------------------------------------------------
# Merged (all-relations-in-one) forms — oracles for the Pallas kernels.
# --------------------------------------------------------------------------

def agg_mean_merged_ref(feat, src, dst, valid):
    """Merged mean aggregation: vmap of :func:`agg_mean_ref` over relations.

    feat: [R, NS, F]; src/dst: [R, EP]; valid: [R, EP] -> [R, NS, F].
    """
    return jax.vmap(agg_mean_ref)(feat, src, dst, valid)


def agg_mean_merged_bwd_ref(feat, src, dst, valid, dout):
    """VJP of the merged mean aggregation w.r.t. ``feat``."""
    _, vjp = jax.vjp(lambda f: agg_mean_merged_ref(f, src, dst, valid), feat)
    return vjp(dout)[0]


def att_agg_merged_ref(feat_src, feat_dst, a_src, a_dst, src, dst, valid):
    """Merged attention aggregation: vmap of :func:`att_agg_ref`.

    feat_src/feat_dst: [R, NS, F]; a_src/a_dst: [R, F]; src/dst/valid: [R, EP].
    """
    return jax.vmap(att_agg_ref)(feat_src, feat_dst, a_src, a_dst, src, dst, valid)
