"""L1 Pallas kernel: merged attention (RGAT) neighbor aggregation.

Same merging idea as ``aggregate.py`` but for RGAT's edge-softmax
aggregation: one Pallas launch replaces the R per-semantic-graph attention
kernel sets. Grid iterates relations; each step computes, on its VMEM block:

    e_ij   = LeakyReLU(a_src . h_i + a_dst . h_j)           (edge scores)
    alpha  = segment-softmax of e over incoming edges of j   (valid only)
    out_j  = sum_i alpha_ij * h_i

As in ``aggregate.py`` there are two formulations: the default
segment-scatter body (what the CPU-PJRT artifacts ship) and an ``mxu=True``
one-hot-matmul body (the TPU/MXU adaptation, DESIGN.md §3), both validated
against ``ref.py``. A finite NEG_INF keeps fully-padded segments NaN-free.

The backward pass for attention is emitted from ``jax.vjp`` of the pure-jnp
reference (one HLO module = still one launch); writing it as a hand-derived
Pallas kernel is possible but buys nothing under interpret=True. DESIGN.md §3.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LEAKY_SLOPE, NEG_INF


def _onehot(idx, n, dtype):
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    return (idx[:, None] == cols).astype(dtype)


def _att_fwd_scatter(fs_ref, fd_ref, asrc_ref, adst_ref, src_ref, dst_ref,
                     valid_ref, out_ref):
    """Single-step merged body over globally-flattened indices (one launch
    = one segment-softmax aggregation over ALL relations, Algorithm 1)."""
    fs = fs_ref[...]  # [R, NS, F] projected source-type features
    fd = fd_ref[...]  # [R, NS, F] projected dest-type features
    a_s = asrc_ref[...]  # [R, F]
    a_d = adst_ref[...]  # [R, F]
    src = src_ref[...]  # [R, EP]
    dst = dst_ref[...]  # [R, EP]
    valid = valid_ref[...]  # [R, EP]
    r, ns, f = fs.shape
    dtype = fs.dtype

    # Per-relation attention logits for every slot (batched matvec), then
    # flatten everything into global (r*NS + slot) coordinates.
    es = jnp.einsum("rnf,rf->rn", fs, a_s,
                    preferred_element_type=jnp.float32).reshape(-1)
    ed = jnp.einsum("rnf,rf->rn", fd, a_d,
                    preferred_element_type=jnp.float32).reshape(-1)
    base = jax.lax.broadcasted_iota(jnp.int32, src.shape, 0) * ns
    gsrc = (src + base).reshape(-1)
    gdst = (dst + base).reshape(-1)
    v = valid.reshape(-1)
    flat = fs.reshape(r * ns, f)

    e = es[gsrc] + ed[gdst]  # [R*EP]
    neg = jnp.asarray(LEAKY_SLOPE, dtype)
    e = jnp.where(e >= 0, e, e * neg)
    e = jnp.where(v > 0, e, jnp.asarray(NEG_INF, dtype))
    seg_max = jnp.full((r * ns,), NEG_INF, dtype).at[gdst].max(e)
    w = jnp.exp(e - seg_max[gdst]) * v  # [R*EP]
    denom = jnp.zeros((r * ns,), dtype).at[gdst].add(w)
    num = jnp.zeros_like(flat).at[gdst].add(w[:, None] * flat[gsrc])
    out_ref[...] = (num / jnp.maximum(denom, 1e-16)[:, None]).reshape(r, ns, f)


def _att_fwd_mxu(fs_ref, fd_ref, asrc_ref, adst_ref, src_ref, dst_ref,
                 valid_ref, out_ref):
    fs = fs_ref[...]
    fd = fd_ref[...]
    a_s = asrc_ref[...]
    a_d = adst_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...]
    ns = fs.shape[0]
    dtype = fs.dtype

    es = jnp.dot(fs, a_s, preferred_element_type=jnp.float32)
    ed = jnp.dot(fd, a_d, preferred_element_type=jnp.float32)
    src_oh = _onehot(src, ns, dtype)  # [EP, NS]
    dst_oh = _onehot(dst, ns, dtype)  # [EP, NS]
    e = jnp.dot(src_oh, es) + jnp.dot(dst_oh, ed)  # [EP]
    neg = jnp.asarray(LEAKY_SLOPE, dtype)
    e = jnp.where(e >= 0, e, e * neg)
    e = jnp.where(valid > 0, e, jnp.asarray(NEG_INF, dtype))

    masked = jnp.where(dst_oh > 0, e[:, None], jnp.asarray(NEG_INF, dtype))
    seg_max = jnp.max(masked, axis=0)  # [NS]
    w = jnp.exp(e - jnp.dot(dst_oh, seg_max)) * valid  # [EP]

    dst_w = dst_oh * w[:, None]  # [EP, NS]
    denom = jnp.sum(dst_w, axis=0)  # [NS]
    gathered = jnp.dot(src_oh, fs, preferred_element_type=jnp.float32)  # [EP, F]
    num = jnp.dot(dst_w.T, gathered, preferred_element_type=jnp.float32)  # [NS, F]
    out_ref[...] = (num / jnp.maximum(denom, 1e-16)[:, None]).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "mxu"))
def att_agg_merged(feat_src, feat_dst, a_src, a_dst, src, dst, valid, *,
                   interpret=True, mxu=False):
    """Merged RGAT attention aggregation, one Pallas launch for R relations.

    feat_src/feat_dst: [R, NS, F] f32; a_src/a_dst: [R, F] f32;
    src/dst: [R, EP] i32; valid: [R, EP] f32. Returns [R, NS, F].
    """
    r, ns, f = feat_src.shape
    ep = src.shape[1]
    out_shape = jax.ShapeDtypeStruct((r, ns, f), feat_src.dtype)
    if mxu:
        vec = pl.BlockSpec((None, ep), lambda i: (i, 0))
        mat = pl.BlockSpec((None, ns, f), lambda i: (i, 0, 0))
        att = pl.BlockSpec((None, f), lambda i: (i, 0))
        return pl.pallas_call(
            _att_fwd_mxu,
            grid=(r,),
            in_specs=[mat, mat, att, att, vec, vec, vec],
            out_specs=mat,
            out_shape=out_shape,
            interpret=interpret,
        )(feat_src, feat_dst, a_src, a_dst, src, dst, valid)
    return pl.pallas_call(
        _att_fwd_scatter,
        out_shape=out_shape,
        interpret=interpret,
    )(feat_src, feat_dst, a_src, a_dst, src, dst, valid)
