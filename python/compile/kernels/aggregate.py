"""L1 Pallas kernel: merged mean neighbor aggregation (HiFuse Algorithm 1).

The paper's key data-side optimization is *merging*: instead of launching one
scatter/gather kernel per semantic graph (R launches), the features and edge
lists of all R semantic graphs are combined so a **single** kernel performs
the whole neighbor-aggregation stage. The Pallas grid iterates relations;
each grid step owns one relation's node slab and edge block in VMEM.

Two kernel-body formulations, selected by `mxu=`:

* ``mxu=False`` (default, what the AOT artifacts ship): gather + segment
  scatter-add, the direct expression of Algorithm 1. Under ``interpret=True``
  this lowers to HLO gather/scatter, which the CPU PJRT backend executes at
  memcpy-like speed — the right formulation for this substrate.
* ``mxu=True``: gather and scatter expressed as dense one-hot matmuls — the
  TPU adaptation (DESIGN.md §3): data-dependent indexing becomes MXU work,
  the native way a real Mosaic lowering would tile this. Kept (and tested)
  as the documented TPU design point; per-step VMEM for the bench profile:

      feat block   512*64*4   = 128 KiB
      one-hots   2*256*512*4  = 512 KiB
      out block    512*64*4   = 128 KiB          total < 1 MiB  (<< 16 MiB)

Kernels are lowered with ``interpret=True`` (the CPU PJRT plugin cannot run
Mosaic custom-calls); numerics are validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot(idx, n, dtype):
    """[E] int32 -> [E, n] one-hot via broadcasted iota (TPU needs >=2D
    iota; this is the MXU-formulation building block)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    return (idx[:, None] == cols).astype(dtype)


def _global_ids(idx, ns):
    """[R, EP] per-relation slot ids -> flattened global row ids
    (r*NS + idx), the merged-tensor coordinates of Algorithm 1."""
    r, ep = idx.shape
    base = jax.lax.broadcasted_iota(jnp.int32, (r, ep), 0) * ns
    return (idx + base).reshape(-1)


def _mean_fwd_scatter(feat_ref, src_ref, dst_ref, valid_ref, out_ref):
    """Single-step merged body: flatten all relations into one global
    gather + one segment scatter-add — Algorithm 1 verbatim (Concat, then
    one Aggregate over the merged tensors)."""
    feat = feat_ref[...]  # [R, NS, F]
    src = src_ref[...]  # [R, EP]
    dst = dst_ref[...]  # [R, EP]
    valid = valid_ref[...]  # [R, EP]
    r, ns, f = feat.shape
    flat = feat.reshape(r * ns, f)
    gsrc = _global_ids(src, ns)
    gdst = _global_ids(dst, ns)
    v = valid.reshape(-1)
    gathered = flat[gsrc] * v[:, None]  # [R*EP, F]
    sums = jnp.zeros_like(flat).at[gdst].add(gathered)
    cnt = jnp.zeros((r * ns,), feat.dtype).at[gdst].add(v)
    out_ref[...] = (sums / jnp.maximum(cnt, 1.0)[:, None]).reshape(r, ns, f)


def _mean_fwd_mxu(feat_ref, src_ref, dst_ref, valid_ref, out_ref):
    """MXU formulation: gather-by-matmul, scatter-by-matmul."""
    feat = feat_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...]
    ns = feat.shape[0]
    src_oh = _onehot(src, ns, feat.dtype)  # [EP, NS]
    gathered = jnp.dot(src_oh, feat, preferred_element_type=jnp.float32)  # [EP, F]
    dst_w = _onehot(dst, ns, feat.dtype) * valid[:, None]  # [EP, NS]
    sums = jnp.dot(dst_w.T, gathered, preferred_element_type=jnp.float32)  # [NS, F]
    cnt = jnp.sum(dst_w, axis=0)  # [NS]
    out_ref[...] = (sums / jnp.maximum(cnt, 1.0)[:, None]).astype(feat.dtype)


def _mean_bwd_scatter(src_ref, dst_ref, valid_ref, dout_ref, dfeat_ref):
    """VJP w.r.t. feat: dfeat[i] += valid_e * dout[dst_e]/cnt[dst_e] for
    each edge e with src_e == i. Same single-step merged structure."""
    src = src_ref[...]  # [R, EP]
    dst = dst_ref[...]
    valid = valid_ref[...]
    dout = dout_ref[...]  # [R, NS, F]
    r, ns, f = dout.shape
    flat = dout.reshape(r * ns, f)
    gsrc = _global_ids(src, ns)
    gdst = _global_ids(dst, ns)
    v = valid.reshape(-1)
    cnt = jnp.maximum(jnp.zeros((r * ns,), dout.dtype).at[gdst].add(v), 1.0)
    dedge = (flat / cnt[:, None])[gdst] * v[:, None]  # [R*EP, F]
    dfeat_ref[...] = jnp.zeros_like(flat).at[gsrc].add(dedge).reshape(r, ns, f)


def _mean_bwd_mxu(src_ref, dst_ref, valid_ref, dout_ref, dfeat_ref):
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...]
    dout = dout_ref[...]
    ns = dout.shape[0]
    dtype = dout.dtype
    dst_w = _onehot(dst, ns, dtype) * valid[:, None]  # [EP, NS]
    cnt = jnp.maximum(jnp.sum(dst_w, axis=0), 1.0)  # [NS]
    dedge = jnp.dot(dst_w, dout / cnt[:, None],
                    preferred_element_type=jnp.float32)  # [EP, F]
    src_oh = _onehot(src, ns, dtype)  # [EP, NS]
    dfeat_ref[...] = jnp.dot(src_oh.T, dedge,
                             preferred_element_type=jnp.float32).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "mxu"))
def agg_mean_merged(feat, src, dst, valid, *, interpret=True, mxu=False):
    """Merged mean aggregation, one Pallas launch for all R relations.

    feat: [R, NS, F] f32; src/dst: [R, EP] i32; valid: [R, EP] f32.
    Returns [R, NS, F]: per relation, row j = mean of feat[src] over valid
    edges with dst == j (0 where a row has no incoming valid edge).
    """
    r, ns, f = feat.shape
    ep = src.shape[1]
    out_shape = jax.ShapeDtypeStruct((r, ns, f), feat.dtype)
    if mxu:
        # TPU formulation: grid over relations, per-relation VMEM blocks.
        return pl.pallas_call(
            _mean_fwd_mxu,
            grid=(r,),
            in_specs=[
                pl.BlockSpec((None, ns, f), lambda i: (i, 0, 0)),
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((None, ns, f), lambda i: (i, 0, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(feat, src, dst, valid)
    # CPU formulation: one step over the fully merged tensors.
    return pl.pallas_call(
        _mean_fwd_scatter,
        out_shape=out_shape,
        interpret=interpret,
    )(feat, src, dst, valid)


@functools.partial(jax.jit, static_argnames=("interpret", "mxu"))
def agg_mean_merged_bwd(src, dst, valid, dout, *, interpret=True, mxu=False):
    """VJP of :func:`agg_mean_merged` w.r.t. ``feat`` (feat not needed: the
    op is linear in feat). src/dst: [R, EP] i32; valid: [R, EP]; dout:
    [R, NS, F]. Returns dfeat [R, NS, F]."""
    r, ns, f = dout.shape
    ep = src.shape[1]
    out_shape = jax.ShapeDtypeStruct((r, ns, f), dout.dtype)
    if mxu:
        return pl.pallas_call(
            _mean_bwd_mxu,
            grid=(r,),
            in_specs=[
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
                pl.BlockSpec((None, ep), lambda i: (i, 0)),
                pl.BlockSpec((None, ns, f), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((None, ns, f), lambda i: (i, 0, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(src, dst, valid, dout)
    return pl.pallas_call(
        _mean_bwd_scatter,
        out_shape=out_shape,
        interpret=interpret,
    )(src, dst, valid, dout)
