# HiFuse-RS build entry points.
#
# The default path is fully self-contained: the pure-Rust SimBackend needs
# no AOT artifacts, no Python, and no PJRT runtime — `make build test`
# works on a clean checkout.
#
# The PJRT backend is opt-in behind the non-default `pjrt` cargo feature:
#   1. `make artifacts`  — emit the AOT HLO modules (needs a jax Python env)
#   2. provide the `xla` crate (see the commented dependency in
#      rust/Cargo.toml — it is not fetchable offline)
#   3. `cargo build --release --features pjrt`
#   4. run with `repro train --backend pjrt --artifacts artifacts/bench`

.PHONY: build test bench bench-json bench-cache bench-serve artifacts fmt clippy

build:
	cargo build --release

test:
	cargo test -q

# Regenerate every paper table/figure into results/ (sim backend, bench
# profile; minutes). HIFUSE_BENCH_QUICK=1 for a fast pass: it shrinks the
# dataset scales AND the epoch counts (the warm-up epoch per measured cell
# is skipped, so quick numbers include first-touch compile/arena costs).
bench: build
	cargo bench --bench paper

# Same matrix, plus the machine-readable perf trajectory written to
# ./BENCH_2.json (per-stage wall/cpu/gpu times — the cpu side broken down
# into sample/select/collect — kernel counts, arena allocs-per-step) and
# the producer-scaling study in results/producer_scaling.{md,csv}. Set
# HIFUSE_PRE_PR_WALL_MS=<ms> (RGCN/aifb hifuse epoch wall of the previous
# build) to record the cross-build speedup.
bench-json: build
	HIFUSE_BENCH_JSON=$(CURDIR)/BENCH_2.json cargo bench --bench paper

# Feature-cache sweep (--cache-frac 0 / 0.25 / 0.5 / 1.0 on RGCN/aifb):
# hit rate vs H2D bytes vs epoch wall, written to
# results/cache_sweep.{md,csv}. The loss column must be identical in every
# row (bit-exactness contract, DESIGN.md §7). HIFUSE_BENCH_QUICK=1 for a
# fast pass.
bench-cache: build
	cargo bench --bench cache_sweep

# Serve latency sweep (open-loop arrival rate vs p50/p95/p99 + throughput
# on RGCN/aifb over 2 replica lanes), written to
# results/serve_latency.{md,csv}. Predictions are bitwise rate- and
# parallelism-independent (DESIGN.md §8); the percentile columns show the
# coalescing-vs-queueing trade-off. HIFUSE_BENCH_QUICK=1 for a fast pass.
bench-serve: build
	cargo bench --bench serve_latency

# OPTIONAL: emit the AOT HLO artifacts for the PJRT backend. The default
# (sim) backend never needs this.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts --profiles tiny,bench

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings
